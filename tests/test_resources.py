"""Property tests for the vector-resource admission API
(repro/sched/resources.py + the vectorized AdmissionController) and the
pluggable placement registry.

Style mirrors tests/test_experts.py: every property is a checker driven
by a deterministic seeded sweep, and the SAME checkers also run under
hypothesis when it happens to be installed (the tier-1 suite must never
depend on it).

The back-compat pins live here too: closed- and open-arrival results for
OURS / ORACLE / PAIRWISE under the default SimConfig (memory+CPU axes,
fcfs placement) must be bit-identical to the pre-redesign scalar
controller — golden values captured at commit 36fe58d, fixed seeds.
"""
import numpy as np
import pytest

from repro.core import (MoEPredictor, OraclePredictor, spark_sim_suite,
                        training_apps)
from repro.core.experts import FAMILIES, MemoryFunction
from repro.core.metrics import run_open_scenario, run_scenario
from repro.core.simulator import (OraclePolicy, OursPolicy, PairwisePolicy,
                                  SimConfig, Simulator)
from repro.sched import (AdmissionController, Arrival, ArrivalConfig,
                         DemandModel, PlacementPolicy, ResourceVector,
                         available_placements, get_placement,
                         register_placement, single_axis)
from repro.sched.resources import AXES

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

N_SWEEP = 20


def _rand_vec(rng, axes=AXES, allow_empty=False) -> ResourceVector:
    n = rng.integers(0 if allow_empty else 1, len(axes) + 1)
    chosen = list(rng.choice(axes, size=n, replace=False))
    return ResourceVector(**{a: float(rng.uniform(0.0, 100.0))
                             for a in chosen})


def _rand_fn(rng) -> MemoryFunction:
    fam = FAMILIES[rng.integers(len(FAMILIES))]
    return MemoryFunction(fam, float(rng.uniform(2.0, 60.0)),
                          float(rng.uniform(0.02, 0.8)))


# --- ResourceVector algebra ------------------------------------------------

def check_vector_algebra(seed):
    rng = np.random.default_rng(seed)
    u, v = _rand_vec(rng), _rand_vec(rng)
    w = u + v
    assert u + v == v + u                       # commutative
    for a in set(u.axes) | set(v.axes):
        assert w.get(a) == pytest.approx(u.get(a) + v.get(a))
    back = w - v
    for a in u.axes:                            # (u+v)-v recovers u
        assert back.get(a) == pytest.approx(u.get(a))
    k = float(rng.uniform(0.1, 3.0))
    for a in u.axes:
        assert (u * k).get(a) == pytest.approx(u.get(a) * k)
    # fits is reflexive and monotone under headroom
    assert u.fits(u)
    assert u.fits(u + v)                        # more budget still fits
    head = (u + v).headroom(u)
    for a in (u + v).axes:
        assert head.get(a) == pytest.approx((u + v).get(a) - u.get(a))


@pytest.mark.parametrize("seed", range(N_SWEEP))
def test_vector_algebra_sweep(seed):
    check_vector_algebra(seed)


def test_vector_rejects_unknown_axis():
    with pytest.raises(ValueError):
        ResourceVector(flux_capacitor=1.0)
    with pytest.raises(ValueError):
        DemandModel({"flux": MemoryFunction("affine", 0.0, 1.0)})


def test_vector_axis_presence_semantics():
    demand = ResourceVector(host_ram=8.0, cpu=0.5)
    # an axis the budget does not carry is unconstrained...
    assert demand.fits(ResourceVector(host_ram=10.0))
    # ...but a present axis with too little capacity rejects
    assert not demand.fits(ResourceVector(host_ram=10.0, cpu=0.4))
    assert demand.fits(ResourceVector(host_ram=10.0, cpu=0.5))


def test_vector_immutable():
    v = ResourceVector(cpu=1.0)
    with pytest.raises(AttributeError):
        v.cpu = 2.0


# --- binding-axis admission ------------------------------------------------

def check_scalar_shim_equals_single_axis(seed):
    """admit(fn, budget_gb) === admit(single-axis DemandModel, single-
    axis vector): bit-identical units/booking on random curves."""
    rng = np.random.default_rng(seed)
    ctrl = AdmissionController()
    fn = _rand_fn(rng)
    budget = float(rng.uniform(1.0, 64.0))
    cap = float(rng.uniform(1.0, 50.0))
    s = ctrl.admit(fn, budget, cap=cap)
    v = ctrl.admit(DemandModel.scalar(fn), single_axis("host_ram", budget),
                   cap=cap)
    assert s.units == v.units
    assert s.mem_gb == v.mem_gb
    assert s.budget_gb == v.budget_gb


@pytest.mark.parametrize("seed", range(N_SWEEP))
def test_scalar_shim_equals_single_axis_sweep(seed):
    check_scalar_shim_equals_single_axis(seed)


def check_admission_monotone_per_axis(seed):
    """Admitted units are monotone non-decreasing in EVERY budget axis."""
    rng = np.random.default_rng(seed)
    ctrl = AdmissionController()
    dm = DemandModel(
        {"host_ram": _rand_fn(rng),
         "hbm": MemoryFunction("affine", float(rng.uniform(0.0, 4.0)),
                               float(rng.uniform(0.05, 2.0)))},
        fixed={"cpu": float(rng.uniform(0.1, 0.9))})
    base = ResourceVector(host_ram=float(rng.uniform(4.0, 40.0)),
                          hbm=float(rng.uniform(4.0, 40.0)),
                          cpu=1.0)
    u0 = ctrl.admit(dm, base, cap=1e6).units
    for axis in base.axes:
        bigger = base + single_axis(axis, float(rng.uniform(0.5, 30.0)))
        u1 = ctrl.admit(dm, bigger, cap=1e6).units
        assert u1 >= u0 - 1e-9, (axis, u0, u1)


@pytest.mark.parametrize("seed", range(N_SWEEP))
def test_admission_monotone_per_axis_sweep(seed):
    check_admission_monotone_per_axis(seed)


def test_binding_axis_reported():
    ctrl = AdmissionController()
    dm = DemandModel({"host_ram": MemoryFunction("affine", 0.0, 1.0),
                      "hbm": MemoryFunction("affine", 0.0, 2.0)})
    # hbm runs out first: inverse 10/2=5 vs 20/1=20
    dec = ctrl.admit(dm, ResourceVector(host_ram=20.0, hbm=10.0))
    assert dec.units == pytest.approx(5.0)
    assert dec.binding_axis == "hbm"
    # the caller's cap binding is reported as None
    dec = ctrl.admit(dm, ResourceVector(host_ram=20.0, hbm=10.0), cap=2.0)
    assert dec.units == pytest.approx(2.0)
    assert dec.binding_axis is None
    # a fixed demand exceeding its axis gates to zero units
    gated = DemandModel({"host_ram": MemoryFunction("affine", 0.0, 1.0)},
                        fixed={"cpu": 0.8})
    dec = ctrl.admit(gated, ResourceVector(host_ram=20.0, cpu=0.5))
    assert dec.units == 0.0 and dec.binding_axis == "cpu"
    # booking never exceeds any budgeted axis
    dec = ctrl.admit(dm, ResourceVector(host_ram=20.0, hbm=10.0))
    for a in dec.booked.axes:
        assert dec.booked.get(a) <= dec.budget.get(a, np.inf) + 1e-9


def test_effective_budget_shades_memory_axes_only():
    ctrl = AdmissionController()
    free = ResourceVector(host_ram=64.0, hbm=32.0, cpu=1.0, net=10.0)
    shaded = ctrl.effective_budget(free, safety_margin=0.25,
                                   conservative=True)
    # memory axes shaded exactly like the scalar path...
    assert shaded["host_ram"] == ctrl.effective_budget(
        64.0, safety_margin=0.25, conservative=True)
    assert shaded["hbm"] == ctrl.effective_budget(
        32.0, safety_margin=0.25, conservative=True)
    # ...average-rate axes untouched
    assert shaded["cpu"] == 1.0 and shaded["net"] == 10.0


def test_demand_model_demand_and_fixed_share_axis():
    dm = DemandModel({"host_ram": MemoryFunction("affine", 1.0, 2.0)},
                     fixed={"host_ram": 3.0, "cpu": 0.5})
    d = dm.demand(2.0)
    assert d["host_ram"] == pytest.approx(1.0 + 2.0 * 2.0 + 3.0)
    assert d["cpu"] == pytest.approx(0.5)
    # the fixed overhead shrinks the curve's budget on the shared axis
    units, axis = dm.inverse(ResourceVector(host_ram=8.0, cpu=1.0))
    assert units == pytest.approx((8.0 - 3.0 - 1.0) / 2.0)
    assert axis == "host_ram"


# --- hypothesis drivers (optional) ----------------------------------------

if HAS_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_hyp_vector_algebra(seed):
        check_vector_algebra(seed)

    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_hyp_scalar_shim(seed):
        check_scalar_shim_equals_single_axis(seed)

    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_hyp_admission_monotone(seed):
        check_admission_monotone_per_axis(seed)


# --- placement registry ----------------------------------------------------

class _J:
    def __init__(self, jid, c_iso, arrival=0.0, unassigned=None,
                 items=None):
        self.jid, self.c_iso, self.arrival = jid, c_iso, arrival
        self.items = items if items is not None else c_iso
        self.unassigned = unassigned if unassigned is not None \
            else self.items


class _H:
    def __init__(self, hid, free):
        self.hid, self._free = hid, free

    def free_vector(self):
        return ResourceVector(host_ram=self._free)


def test_registry_round_trip_every_policy():
    assert set(available_placements()) >= {"fcfs", "sjf", "best-fit",
                                           "arrival-aware"}
    for name in available_placements():
        pol = get_placement(name)
        assert isinstance(pol, PlacementPolicy)
        assert pol.name == name
        # ordering hooks are permutations of the input
        jobs = [_J(i, c_iso=10.0 - i, arrival=float(i))
                for i in range(5)]
        hosts = [_H(i, free=float((i * 3) % 7)) for i in range(5)]
        oj = pol.order_jobs(jobs, now=100.0)
        oh = pol.order_hosts(jobs[0], hosts)
        assert sorted(j.jid for j in oj) == [0, 1, 2, 3, 4]
        assert sorted(h.hid for h in oh) == [0, 1, 2, 3, 4]
    with pytest.raises(KeyError):
        get_placement("no-such-policy")


def test_placement_orderings():
    jobs = [_J(0, c_iso=8.0, arrival=0.0),
            _J(1, c_iso=2.0, arrival=5.0),
            _J(2, c_iso=4.0, arrival=9.0)]
    hosts = [_H(0, 5.0), _H(1, 1.0), _H(2, 3.0)]
    assert [j.jid for j in get_placement("fcfs").order_jobs(jobs)] \
        == [0, 1, 2]
    assert [h.hid for h in get_placement("fcfs").order_hosts(None, hosts)] \
        == [0, 1, 2]
    # sjf: remaining isolated time ascending (2.0, 4.0, 8.0)
    assert [j.jid for j in get_placement("sjf").order_jobs(jobs)] \
        == [1, 2, 0]
    # best-fit: tightest host first
    assert [h.hid for h in
            get_placement("best-fit").order_hosts(None, hosts)] \
        == [1, 2, 0]
    # arrival-aware at t=10: urgency (10-a)/c_iso = 1.25, 2.5, 0.25
    assert [j.jid for j in
            get_placement("arrival-aware").order_jobs(jobs, now=10.0)] \
        == [1, 0, 2]


def test_register_placement_extension_point():
    @register_placement("_test-reverse")
    class _Rev(PlacementPolicy):
        def order_jobs(self, jobs, now=0.0):
            return list(jobs)[::-1]
    try:
        assert "_test-reverse" in available_placements()
        jobs = [_J(i, 1.0) for i in range(3)]
        assert [j.jid for j in
                get_placement("_test-reverse").order_jobs(jobs)] \
            == [2, 1, 0]
    finally:
        from repro.sched.placement import _REGISTRY
        _REGISTRY.pop("_test-reverse", None)


# --- end-to-end: placements drive the simulator, shim stays bit-exact ------

@pytest.fixture(scope="module")
def suite():
    apps = spark_sim_suite()
    moe = MoEPredictor().fit(training_apps(apps))
    return apps, moe


def test_every_placement_runs_and_conserves(suite):
    """Each registered policy drives a full open-arrival run to
    completion (work conservation holds; only ordering differs)."""
    apps, moe = suite
    from repro.sched import poisson_arrivals
    arrivals = poisson_arrivals(
        apps, ArrivalConfig(rate_per_s=0.05, n_jobs=10), seed=3)
    stps = {}
    for name in ("fcfs", "sjf", "best-fit", "arrival-aware"):
        cfg = SimConfig(n_hosts=6, placement=name)
        sim = Simulator(None, OursPolicy(moe), cfg, seed=3,
                        arrivals=arrivals)
        out = sim.run()
        for j in sim.jobs:
            assert j.finish is not None
            assert j.done == pytest.approx(j.items, rel=1e-6)
        stps[name] = out["stp"]
    assert stps["fcfs"] > 0


def test_policy_placement_override_beats_cfg(suite):
    apps, moe = suite
    jobs = [(apps[i], 30.0) for i in (0, 5, 11, 17)]
    cfg = SimConfig(n_hosts=4, placement="fcfs")
    r_cfg_sjf = Simulator(
        jobs, OursPolicy(moe), SimConfig(n_hosts=4, placement="sjf"),
        seed=1).run()
    r_override = Simulator(
        jobs, OursPolicy(moe, placement="sjf"), cfg, seed=1).run()
    assert r_override["stp"] == r_cfg_sjf["stp"]
    assert r_override["antt"] == r_cfg_sjf["antt"]


# --- multi-axis scenario: a non-primary axis binds -------------------------

def test_secondary_axis_binds_admission(suite):
    """HBM-primary hosts with a small host-staging-RAM axis: admission
    must be bound by host_ram for some placements, and booked host_ram
    must never exceed its capacity."""
    apps, moe = suite
    from dataclasses import replace
    # slope chosen so one chunk's staging (~4.3 GB) fits the 8 GB axis
    # but a second co-located executor is bound by what's left
    staged = [replace(a, aux_demand={"host_ram": MemoryFunction(
        "affine", 0.1, 0.1)}) for a in apps]
    cfg = SimConfig(n_hosts=6, host_mem_gb=4096.0, min_alloc_gb=4.0,
                    primary_axis="hbm", extra_capacity={"host_ram": 8.0})
    sim = Simulator([(staged[i], 1000.0) for i in (0, 3, 7, 11)],
                    OursPolicy(moe), cfg, seed=2)
    spawned = []
    orig = sim._spawn

    def spy(job, host, items, mt, mc, delay=0.0):
        e = orig(job, host, items, mt, mc, delay)
        spawned.append(e)
        used = sum(x.claimed_vec.get("host_ram", 0.0)
                   for x in host.execs)
        assert used <= 8.0 + 1e-6
        return e

    sim._spawn = spy
    out = sim.run()
    assert spawned
    assert out["binding_axes"].get("host_ram", 0) > 0


def test_empty_host_override_respects_cpu_gate(suite):
    """The empty-host chunk override relaxes only the PRIMARY memory
    axis: a job whose CPU load exceeds the host slack must never spawn,
    even on an idle host (the pre-redesign dispatcher semantics)."""
    apps, moe = suite
    from dataclasses import replace
    hungry = [replace(a, cpu_load=0.9) for a in apps[:4]]
    cfg = SimConfig(n_hosts=4, cpu_slack=0.5, max_sim_time=1e5)
    sim = Simulator([(h, 30.0) for h in hungry], OursPolicy(moe), cfg,
                    seed=0)
    spawned = []
    orig = sim._spawn
    sim._spawn = lambda *a, **k: spawned.append(a) or orig(*a, **k)
    out = sim.run()
    assert not spawned                      # gate held on every host
    assert "cpu" not in out["binding_axes"]
    assert out["unfinished"] == 4


def test_empty_host_override_respects_secondary_axis(suite):
    """A bound secondary axis (no overrun consequence model) must not be
    overridden by the empty-host chunk floor: bookings stay within the
    axis capacity even when every placement opens an idle host."""
    apps, moe = suite
    from dataclasses import replace
    # staging at chunk scale (~41.7 items -> ~21 GB) dwarfs the 8 GB
    # axis; admission must shrink the split instead of forcing a chunk
    staged = [replace(a, aux_demand={"host_ram": MemoryFunction(
        "affine", 0.1, 0.5)}) for a in apps]
    cfg = SimConfig(n_hosts=6, host_mem_gb=4096.0, min_alloc_gb=4.0,
                    primary_axis="hbm", extra_capacity={"host_ram": 8.0},
                    max_sim_time=1e7)
    sim = Simulator([(staged[i], 1000.0) for i in (0, 3, 7)],
                    OursPolicy(moe), cfg, seed=2)
    spawned = []
    orig = sim._spawn

    def spy(job, host, items, mt, mc, delay=0.0):
        e = orig(job, host, items, mt, mc, delay)
        spawned.append(e)
        used = sum(x.claimed_vec.get("host_ram", 0.0)
                   for x in host.execs)
        assert used <= 8.0 + 1e-6, used
        return e

    sim._spawn = spy
    out = sim.run()
    assert spawned                        # the axis shrank, not starved
    assert out["binding_axes"].get("host_ram", 0) > 0


def test_admit_batch_reports_forced_axes():
    """The forced flag names the violated axes — a host_ram-forced
    admission must not be misreported as an hbm overrun."""
    ctrl = AdmissionController()
    dm = DemandModel({"hbm": MemoryFunction("affine", 0.0, 5.0),
                      "host_ram": MemoryFunction("affine", 0.0, 1.0)},
                     primary_axis="hbm")
    dec = ctrl.admit_batch(
        dm, ResourceVector(hbm=10.0, host_ram=0.5), min_batch=1)
    assert dec.units == 1 and dec.info["forced"]
    assert dec.info["forced_axes"] == ["host_ram"]   # hbm (5<=10) fits
    assert dec.info["demand"]["host_ram"] == pytest.approx(1.0)
    ok = ctrl.admit_batch(dm, ResourceVector(hbm=10.0, host_ram=2.0))
    assert not ok.info["forced"] and ok.info["forced_axes"] == []


def test_cpu_gate_moved_into_controller(suite):
    """A host whose CPU slack is exhausted must admit nothing even with
    plenty of free memory — the gate now lives in the DemandModel's
    fixed cpu axis, not the dispatcher."""
    apps, moe = suite
    ctrl = AdmissionController()
    fn = MemoryFunction("affine", 0.0, 1.0)
    dm = DemandModel({"host_ram": fn}, fixed={"cpu": 0.6})
    ok = ctrl.admit(dm, ResourceVector(host_ram=32.0, cpu=0.7))
    assert ok.units > 0
    gated = ctrl.admit(dm, ResourceVector(host_ram=32.0, cpu=0.5))
    assert gated.units == 0.0 and gated.binding_axis == "cpu"


# --- golden back-compat pins (pre-redesign scalar controller) --------------

GOLDEN_CLOSED = {   # run_scenario(n_jobs=9, n_mixes=3, n_hosts=12, seed=7)
    "ours": (5.767868544931616, 2.71079337041143,
             -0.3074459529260183, 0),
    "oracle": (6.3699925720923645, 1.8950316893180805,
               0.40447242501767683, 0),
    "pairwise": (2.9885133539911806, 273.59043173481683,
                 -0.03958673182490006, 101),
}
GOLDEN_OPEN = {     # run_open_scenario(rate=0.05, n_jobs=12, n_hosts=8,
    "ours": (8.603874583612448, 4.06171787327101, 0),      # 2 streams,
    "oracle": (8.689598499339828, 3.9819882349936964, 0),  # seed=5)
    "pairwise": (3.4465593523468114, 127.74640323642231, 27),
}


def _factories(moe):
    return {
        "ours": lambda m: OursPolicy(moe),
        "oracle": lambda m: OraclePolicy(OraclePredictor()),
        "pairwise": lambda m: PairwisePolicy(),
    }


def test_scalar_shim_closed_results_bit_identical(suite):
    apps, moe = suite
    for name, factory in _factories(moe).items():
        r = run_scenario(apps, factory, n_jobs=9, n_mixes=3,
                         cfg=SimConfig(n_hosts=12), seed=7)
        stp, antt, red, oom = GOLDEN_CLOSED[name]
        assert r.stp_gmean == stp, name
        assert r.antt_gmean == antt, name
        assert r.antt_reduction_mean == red, name
        assert r.oom_total == oom, name
        # the default config's only resource binder is primary memory
        assert set(r.binding_axes) <= {"host_ram", "cap"}, name


def test_scalar_shim_open_results_bit_identical(suite):
    apps, moe = suite
    acfg = ArrivalConfig(rate_per_s=0.05, n_jobs=12)
    for name, factory in _factories(moe).items():
        r = run_open_scenario(apps, factory, acfg, n_streams=2,
                              cfg=SimConfig(n_hosts=8), seed=5)
        stp, antt, oom = GOLDEN_OPEN[name]
        assert r["stp_gmean"] == stp, name
        assert r["antt_gmean"] == antt, name
        assert r["oom_total"] == oom, name
