"""Property tests for the memory-function experts.

Runs under plain pytest: each property is a checker function driven by a
deterministic parametrized sweep (families x seeded (m, b, x) draws).
When ``hypothesis`` happens to be installed, the same checkers are ALSO
driven by real property-based search — but the tier-1 suite must never
depend on it (a hard import here used to abort collection under ``-x``).
"""
import numpy as np
import pytest

from repro.core import experts
from repro.core.experts import MemoryFunction, calibrate_two_point

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

N_SWEEP = 15  # seeded draws per family per property


def _fn(family, m, b):
    if family == "power":
        return MemoryFunction("power", m, min(max(b, 0.1), 0.9))
    if family == "exp_saturation":
        return MemoryFunction("exp_saturation", m * 10, min(b, 0.5) / 10)
    if family == "log":
        return MemoryFunction("log", m + 5.0, min(max(b, 0.3), 5.0))
    return MemoryFunction("affine", m, b / 10)


def _draw(family, seed):
    """Deterministic (m, b, x1, budget) draw in the same ranges the
    hypothesis strategies use (str hash is salted per process — use a
    stable digest)."""
    rng = np.random.default_rng([sum(family.encode()), seed])
    m, b = rng.uniform(0.1, 50.0, size=2)
    x1 = rng.uniform(1.0, 100.0)
    budget = rng.uniform(0.5, 60.0)
    return float(m), float(b), float(x1), float(budget)


SWEEP = [(fam, seed) for fam in experts.FAMILIES
         for seed in range(N_SWEEP)]


# --- property checkers (shared by the sweep and hypothesis paths) ----------

def check_two_point_calibration_exact(family, m, b, x1):
    """Noiseless two-point calibration recovers the function (the paper's
    runtime path)."""
    fn = _fn(family, m, b)
    x2 = x1 * 2.0
    y1, y2 = float(fn(x1)), float(fn(x2))
    if y2 <= y1 * 1.03:  # saturated probes -> guarded path, skip exactness
        return
    cal = calibrate_two_point(family, x1, y1, x2, y2)
    for x in [x1 * 0.5, x1, x2, x2 * 2.0]:
        t, p = float(fn(x)), float(cal(x))
        assert abs(p - t) / max(abs(t), 1e-6) < 0.05, (family, x, t, p)


def check_inverse_property(family, m, b, budget):
    """x* = f^-1(y) satisfies f(x*) <~ y (allocation ~never over-budget;
    2% slack covers pow-roundtrip error at extreme 1/b exponents)."""
    fn = _fn(family, m, b)
    x = fn.inverse(budget)
    if np.isfinite(x) and x > 0:
        assert float(fn(x)) <= budget * 1.02 + 1e-6


def check_best_family_recovers_generator(family, m, b):
    """Offline fitting identifies the generating family (or an
    indistinguishable one) on clean curves."""
    fn = _fn(family, m, b)
    xs = np.geomspace(0.1, 1000.0, 12)
    ys = np.asarray(fn(xs))
    if np.any(ys <= 0):
        return
    best, errs = experts.best_family(xs, ys)
    assert errs[family] < 0.05  # generator always fits well
    assert min(errs.values()) == errs[best.family]


def check_fit_matches_curve(family, m, b):
    fn = _fn(family, m, b)
    xs = np.geomspace(0.2, 500.0, 10)
    ys = np.asarray(fn(xs))
    if np.any(ys <= 0):
        return
    fit = experts.fit(family, xs, ys)
    assert experts.relative_error(fit, xs, ys) < 0.05


# --- deterministic parametrized sweep (always runs) ------------------------

@pytest.mark.parametrize("family,seed", SWEEP)
def test_two_point_calibration_exact_on_clean_data(family, seed):
    m, b, x1, _ = _draw(family, seed)
    check_two_point_calibration_exact(family, m, b, x1)


@pytest.mark.parametrize("family,seed", SWEEP)
def test_inverse_property(family, seed):
    m, b, _, budget = _draw(family, seed)
    check_inverse_property(family, m, b, budget)


@pytest.mark.parametrize("family,seed", SWEEP)
def test_best_family_recovers_generator(family, seed):
    m, b, _, _ = _draw(family, seed)
    check_best_family_recovers_generator(family, m, b)


@pytest.mark.parametrize("family,seed", SWEEP)
def test_fit_matches_curve(family, seed):
    m, b, _, _ = _draw(family, seed)
    check_fit_matches_curve(family, m, b)


# --- hypothesis-driven search (bonus coverage when available) --------------

if HAS_HYPOTHESIS:
    FAMS = st.sampled_from(experts.FAMILIES)
    POS = st.floats(min_value=0.1, max_value=50.0, allow_nan=False)

    @settings(max_examples=60, deadline=None)
    @given(FAMS, POS, POS, st.floats(min_value=1.0, max_value=100.0))
    def test_two_point_calibration_hypothesis(family, m, b, x1):
        check_two_point_calibration_exact(family, m, b, x1)

    @settings(max_examples=60, deadline=None)
    @given(FAMS, POS, POS, st.floats(min_value=0.5, max_value=60.0))
    def test_inverse_property_hypothesis(family, m, b, budget):
        check_inverse_property(family, m, b, budget)

    @settings(max_examples=40, deadline=None)
    @given(FAMS, POS, POS)
    def test_best_family_recovers_generator_hypothesis(family, m, b):
        check_best_family_recovers_generator(family, m, b)

    @settings(max_examples=40, deadline=None)
    @given(FAMS, POS, POS)
    def test_fit_matches_curve_hypothesis(family, m, b):
        check_fit_matches_curve(family, m, b)


# --- regression tests ------------------------------------------------------

def test_exp_saturation_guard():
    """Flat probe pairs (saturated curve + noise) must NOT produce absurd
    m (the OOM-storm regression test)."""
    cal = calibrate_two_point("exp_saturation", 50.0, 20.0, 100.0, 20.1)
    assert cal.m < 100.0
    assert 15.0 < float(cal(1000.0)) < 30.0


def test_monotonicity():
    for fam in experts.FAMILIES:
        fn = _fn(fam, 5.0, 2.0)
        xs = np.geomspace(0.1, 100, 50)
        ys = np.asarray(fn(xs))
        assert np.all(np.diff(ys) >= -1e-9), fam


def test_power_inverse_flat_fit_saturates_to_inf():
    """Near-flat power fits (tiny b) must return inf, not overflow —
    surfaced by calibrating power on an almost-constant affine footprint
    in the open-arrival stream."""
    fn = MemoryFunction("power", 5.0, 1e-4)
    x = fn.inverse(60.0)   # (12)**(1e4) overflows a float pow
    assert x == np.inf
    # budget below the curve at the x-clamp still inverts to ~0
    assert fn.inverse(1e-6) == 0.0
