"""Property-based tests (hypothesis) for the memory-function experts."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import experts
from repro.core.experts import MemoryFunction, calibrate_two_point

FAMS = st.sampled_from(experts.FAMILIES)
POS = st.floats(min_value=0.1, max_value=50.0, allow_nan=False)


def _fn(family, m, b):
    if family == "power":
        return MemoryFunction("power", m, min(max(b, 0.1), 0.9))
    if family == "exp_saturation":
        return MemoryFunction("exp_saturation", m * 10, min(b, 0.5) / 10)
    if family == "log":
        return MemoryFunction("log", m + 5.0, min(max(b, 0.3), 5.0))
    return MemoryFunction("affine", m, b / 10)


@settings(max_examples=60, deadline=None)
@given(FAMS, POS, POS, st.floats(min_value=1.0, max_value=100.0))
def test_two_point_calibration_exact_on_clean_data(family, m, b, x1):
    """Noiseless two-point calibration recovers the function (the paper's
    runtime path)."""
    fn = _fn(family, m, b)
    x2 = x1 * 2.0
    y1, y2 = float(fn(x1)), float(fn(x2))
    if y2 <= y1 * 1.03:  # saturated probes -> guarded path, skip exactness
        return
    cal = calibrate_two_point(family, x1, y1, x2, y2)
    for x in [x1 * 0.5, x1, x2, x2 * 2.0]:
        t, p = float(fn(x)), float(cal(x))
        assert abs(p - t) / max(abs(t), 1e-6) < 0.05, (family, x, t, p)


@settings(max_examples=60, deadline=None)
@given(FAMS, POS, POS, st.floats(min_value=0.5, max_value=60.0))
def test_inverse_property(family, m, b, budget):
    """x* = f^-1(y) satisfies f(x*) <~ y (allocation ~never over-budget;
    2% slack covers pow-roundtrip error at extreme 1/b exponents)."""
    fn = _fn(family, m, b)
    x = fn.inverse(budget)
    if np.isfinite(x) and x > 0:
        assert float(fn(x)) <= budget * 1.02 + 1e-6


@settings(max_examples=40, deadline=None)
@given(FAMS, POS, POS)
def test_best_family_recovers_generator(family, m, b):
    """Offline fitting identifies the generating family (or an
    indistinguishable one) on clean curves."""
    fn = _fn(family, m, b)
    xs = np.geomspace(0.1, 1000.0, 12)
    ys = np.asarray(fn(xs))
    if np.any(ys <= 0):
        return
    best, errs = experts.best_family(xs, ys)
    assert errs[family] < 0.05  # generator always fits well
    assert min(errs.values()) == errs[best.family]


@settings(max_examples=40, deadline=None)
@given(FAMS, POS, POS)
def test_fit_matches_curve(family, m, b):
    fn = _fn(family, m, b)
    xs = np.geomspace(0.2, 500.0, 10)
    ys = np.asarray(fn(xs))
    if np.any(ys <= 0):
        return
    fit = experts.fit(family, xs, ys)
    assert experts.relative_error(fit, xs, ys) < 0.05


def test_exp_saturation_guard():
    """Flat probe pairs (saturated curve + noise) must NOT produce absurd
    m (the OOM-storm regression test)."""
    cal = calibrate_two_point("exp_saturation", 50.0, 20.0, 100.0, 20.1)
    assert cal.m < 100.0
    assert 15.0 < float(cal(1000.0)) < 30.0


def test_monotonicity():
    for fam in experts.FAMILIES:
        fn = _fn(fam, 5.0, 2.0)
        xs = np.geomspace(0.1, 100, 50)
        ys = np.asarray(fn(xs))
        assert np.all(np.diff(ys) >= -1e-9), fam
