"""Loop-aware HLO analyzer: trip counts, dot flops, collective bytes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.hlo_analyzer import analyze, parse_module

SYNTH = """
HloModule test, is_scheduled=true

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%y), replica_groups=[4]<=[4], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]{1,0}) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]{1,0}) tuple(%zero, %a)
  %w2 = (s32[], f32[8,16]{1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_synthetic_module_loop_accounting():
    cost = analyze(SYNTH)
    # one dot of 2*8*16*16 flops, executed 12 times
    assert cost.flops == 2 * 8 * 16 * 16 * 12
    # one all-reduce of 8*16*4 bytes, 12 times
    assert cost.collective_bytes["all-reduce"] == 8 * 16 * 4 * 12
    assert cost.collective_counts["all-reduce"] == 12
    assert cost.loops and cost.loops[0]["trip"] == 12


def test_trip_count_fallback_from_init_constant():
    txt = SYNTH.replace(', backend_config={"known_trip_count":{"n":"12"}}',
                        "")
    cost = analyze(txt)
    # falls back to the s32 constant in the init tuple... init has 0 only;
    # the bound constant (12) lives in the condition — fallback yields >= 1
    assert cost.loops[0]["trip"] >= 1


def test_parse_module_structure():
    comps = parse_module(SYNTH)
    assert "__entry__" in comps
    assert any(i.opcode == "while" for i in comps["__entry__"].instrs)


def test_real_scan_module_flops_scale_with_depth():
    """Flops of a scanned stack scale ~linearly with layer count."""
    def make(n_layers):
        def f(w, x):
            def body(h, wl):
                return jnp.tanh(h @ wl), None
            h, _ = jax.lax.scan(body, x, w)
            return h
        w = jnp.zeros((n_layers, 32, 32), jnp.float32)
        x = jnp.zeros((8, 32), jnp.float32)
        return jax.jit(f).lower(w, x).compile().as_text()

    c4 = analyze(make(4))
    c8 = analyze(make(8))
    assert c4.flops > 0
    ratio = c8.flops / c4.flops
    assert 1.7 < ratio < 2.3, ratio


def test_gather_bytes_not_full_table():
    """Embedding gather counts the gathered rows, not the whole table."""
    def f(table, ids):
        return table[ids]
    table = jnp.zeros((50_000, 64), jnp.float32)
    ids = jnp.zeros((8,), jnp.int32)
    txt = jax.jit(f).lower(table, ids).compile().as_text()
    cost = analyze(txt)
    table_bytes = 50_000 * 64 * 4
    assert cost.hbm_bytes < table_bytes * 0.5
