"""Per-arch smoke tests (reduced configs): forward/train/prefill/decode on
CPU, output shapes + no NaNs; decode==forward consistency for a
representative subset."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_shape, concrete_inputs
from repro.models import model


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = model.init(cfg, jax.random.key(0))
    batch = concrete_inputs(cfg, smoke_shape("train"))
    h, aux = model.forward_train(params, cfg, batch)
    logits = model.lm_logits(params, cfg, h)
    B = batch["tokens"].shape[0]
    assert h.shape[0] == B and h.shape[-1] == cfg.d_model
    assert logits.shape[-1] == cfg.vocab_size
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    params = model.init(cfg, jax.random.key(0))
    pbatch = concrete_inputs(cfg, smoke_shape("prefill"))
    pbatch.pop("labels", None)
    pbatch.pop("loss_mask", None)
    last, cache = model.prefill(params, cfg, pbatch, max_len=48)
    assert not bool(jnp.isnan(last).any())
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    for _ in range(2):
        lg, cache = model.decode_step(params, cfg, cache, tok)
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        assert not bool(jnp.isnan(lg).any())
    assert int(cache["len"]) == pbatch["tokens"].shape[1] + (
        pbatch.get("patch_embeds").shape[1]
        if "patch_embeds" in pbatch else 0) + 2


@pytest.mark.parametrize("arch", ["qwen3-14b", "gemma2-27b", "mamba2-780m",
                                  "zamba2-2.7b", "whisper-large-v3"])
@pytest.mark.slow
def test_decode_matches_forward(arch):
    """prefill(t[:k]) + decode(t[k:]) logits == full forward logits."""
    cfg = get_config(arch, smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    params = model.init(cfg, jax.random.key(1))
    rng = np.random.default_rng(0)
    B, S, K = 2, 16, 10
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(0, 0.1, (B, 8, cfg.d_model)), jnp.float32)
    h, _ = model.forward_train(params, cfg, batch)
    full = model.lm_logits(params, cfg, h)
    pb = dict(batch, tokens=tokens[:, :K])
    last, cache = model.prefill(params, cfg, pb, max_len=S + 4)
    errs = [float(jnp.max(jnp.abs(last[:, 0] - full[:, K - 1])))]
    for i in range(K, S):
        lg, cache = model.decode_step(params, cfg, cache,
                                      tokens[:, i:i + 1])
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, i]))))
    assert max(errs) < 2e-3, errs


@pytest.mark.slow
def test_moe_decode_matches_forward_with_nodrop_capacity():
    """MoE consistency requires drop-free capacity (documented semantics:
    capacity drops depend on the token population)."""
    cfg = get_config("qwen3-moe-30b-a3b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32",
        capacity_factor=16.0)
    params = model.init(cfg, jax.random.key(1))
    rng = np.random.default_rng(0)
    B, S, K = 2, 16, 10
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    h, _ = model.forward_train(params, cfg, {"tokens": tokens})
    full = model.lm_logits(params, cfg, h)
    last, cache = model.prefill(params, cfg, {"tokens": tokens[:, :K]},
                                max_len=S + 2)
    errs = [float(jnp.max(jnp.abs(last[:, 0] - full[:, K - 1])))]
    for i in range(K, S):
        lg, cache = model.decode_step(params, cfg, cache,
                                      tokens[:, i:i + 1])
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, i]))))
    assert max(errs) < 2e-3, errs


def test_param_counts_match_advertised_scale():
    """Full configs land near their advertised parameter counts."""
    from repro.utils.tree import tree_size
    expected = {
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "qwen3-14b": (12e9, 16e9),
        "deepseek-coder-33b": (30e9, 36e9),
        "gemma2-27b": (24e9, 30e9),
        "qwen3-moe-30b-a3b": (26e9, 34e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "mamba2-780m": (0.6e9, 1.0e9),
        "zamba2-2.7b": (2.2e9, 3.3e9),
        "pixtral-12b": (10e9, 14e9),
        "whisper-large-v3": (1.2e9, 2.1e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        n = tree_size(model.abstract(cfg))
        assert lo <= n <= hi, (arch, n / 1e9)


def test_abstract_and_init_agree():
    cfg = get_config("qwen3-0.6b", smoke=True)
    abst = model.abstract(cfg)
    conc = model.init(cfg, jax.random.key(0))
    fa = jax.tree.map(lambda x: (x.shape, str(x.dtype)), abst)
    fc = jax.tree.map(lambda x: (x.shape, str(x.dtype)), conc)
    assert fa == fc
