"""Sharding-rule validity for every arch: specs divide, no duplicate axes,
ZeRO-1 opt specs well-formed. Uses a fake small mesh (no 512 devices)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, input_specs
from repro.launch import sharding as shd
from repro.models import model
from repro.utils.tree import flatten_with_paths


class FakeMesh:
    """Shape-only stand-in for jax.Mesh (rules only read .shape/.axis_names)."""

    def __init__(self, shape_dict):
        self.shape = shape_dict
        self.axis_names = tuple(shape_dict)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _axis_sizes(mesh, part):
    if part is None:
        return 1
    parts = part if isinstance(part, (tuple, list)) else [part]
    n = 1
    for p in parts:
        n *= mesh.shape[p]
    return n


def _validate(spec_tree, abstract_tree, mesh):
    flat_s = flatten_with_paths(spec_tree)
    flat_a = flatten_with_paths(abstract_tree)
    for (path, spec), (_, leaf) in zip(flat_s, flat_a):
        assert isinstance(spec, P)
        used = []
        for part in spec:
            if part is None:
                continue
            parts = part if isinstance(part, (tuple, list)) else [part]
            used += list(parts)
        assert len(used) == len(set(used)), (path, spec)
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        for dim, part in zip(leaf.shape, list(spec) + [None] * leaf.ndim):
            assert dim % _axis_sizes(mesh, part) == 0, (path, spec,
                                                        leaf.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [MESH, MESH_MP], ids=["single", "multi"])
def test_param_specs_valid(arch, mesh):
    cfg = get_config(arch)
    abst = model.abstract(cfg)
    specs = shd.param_specs(cfg, abst, mesh, kind="train")
    _validate(specs, abst, mesh)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_zero1_opt_specs_valid(arch):
    cfg = get_config(arch)
    abst = model.abstract(cfg)
    ps = shd.param_specs(cfg, abst, MESH, kind="train")
    zs = shd.zero1_opt_specs(ps, abst, MESH)
    _validate(zs, abst, MESH)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_valid(arch):
    cfg = get_config(arch)
    cache = model.init_cache(cfg, 128, 1024, abstract_only=True)
    specs = shd.cache_specs(cfg, cache, MESH)
    _validate(specs, cache, MESH)


def test_batch_axes_divisibility():
    assert shd.batch_axes(MESH, 256) == "data"
    assert shd.batch_axes(MESH_MP, 256) == ("pod", "data")
    assert shd.batch_axes(MESH_MP, 1) is None  # long_500k: B=1 replicated


def test_fix_spec_drops_nondividing_axes():
    s = shd.fix_spec(P("model", None), (51_866, 1280), MESH)
    assert s == P(None, None)
    s2 = shd.fix_spec(P("model", None), (256_000, 1280), MESH)
    assert s2 == P("model", None)


def test_expert_weights_get_ep_over_data():
    cfg = get_config("kimi-k2-1t-a32b")
    abst = model.abstract(cfg)
    specs = shd.param_specs(cfg, abst, MESH, kind="train")
    flat = dict(flatten_with_paths(specs))
    wg = flat["blocks/moe/w_gate"]
    assert wg[1] == "data" and "model" in wg  # [L, E, d, f]


def test_input_specs_cover_all_cells():
    from repro.configs import all_cells
    cells = all_cells()
    assert len(cells) == 32  # 10 archs x 3 + 2 long_500k
    for arch, shape in cells:
        cfg = get_config(arch)
        specs = input_specs(cfg, SHAPES[shape])
        assert specs, (arch, shape)
        leaves = jax.tree.leaves(specs)
        assert all(hasattr(s, "shape") for s in leaves)
        total = sum(int(np.prod(s.shape)) for s in leaves)
        assert total > 0
