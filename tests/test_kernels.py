"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

rng = np.random.default_rng(42)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (1, 64, 2, 2, 16), (2, 96, 4, 2, 32), (1, 128, 8, 1, 64),
    (2, 80, 4, 4, 16),  # padded (80 % 32 != 0)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, Hq, Hkv, D, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    q = jnp.asarray(rng.normal(0, 1, (B, S, Hq, D)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), dtype)
    out = flash_attention(q, k, v, blk_q=32, blk_k=32)
    ref = jnp.moveaxis(attention_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        scale=D ** -0.5), 1, 2)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("window,softcap,causal", [
    (16, 0.0, True), (0, 30.0, True), (32, 50.0, True), (0, 0.0, False),
])
def test_flash_attention_variants(window, softcap, causal):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    B, S, Hq, Hkv, D = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(0, 1, (B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          attn_softcap=softcap, blk_q=32, blk_k=32)
    ref = jnp.moveaxis(attention_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        scale=D ** -0.5, causal=causal, window=window, softcap=softcap),
        1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,Hq,Hkv,D,ln", [
    (1, 64, 2, 2, 16, 10), (2, 96, 8, 2, 32, 95), (1, 64, 4, 1, 64, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, S, Hq, Hkv, D, ln, dtype):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    q = jnp.asarray(rng.normal(0, 1, (B, 1, Hq, D)), dtype)
    kc = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), dtype)
    vc = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), dtype)
    out = decode_attention(q, kc, vc, ln, blk_k=32)
    ref = jnp.moveaxis(decode_attention_ref(
        jnp.moveaxis(q, 2, 1), kc, vc, jnp.full((B,), ln + 1, jnp.int32),
        scale=D ** -0.5), 1, 2)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("B,S,Hq,Hkv,D,ln", [
    (1, 50, 3, 1, 16, 7),     # odd S, odd Hq (padding + GQA remainder)
    (2, 33, 5, 1, 32, 30),    # S far from the 32-wide block grid
    (1, 96, 6, 3, 48, 11),    # non-pow2 head dim, odd KV head count
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_odd_shapes(B, S, Hq, Hkv, D, ln, dtype):
    """Non-power-of-two sweeps vs the jnp oracle in both dtypes."""
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    q = jnp.asarray(rng.normal(0, 1, (B, 1, Hq, D)), dtype)
    kc = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), dtype)
    vc = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), dtype)
    out = decode_attention(q, kc, vc, ln, blk_k=32)
    ref = jnp.moveaxis(decode_attention_ref(
        jnp.moveaxis(q, 2, 1), kc, vc, jnp.full((B,), ln + 1, jnp.int32),
        scale=D ** -0.5), 1, 2)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


def test_decode_attention_matches_model_path():
    """Kernel agrees with the model's own decode_attention (XLA path)."""
    from repro.kernels.decode_attention.ops import decode_attention as kd
    from repro.models.attention import decode_attention as md
    B, S, Hq, Hkv, D, ln = 2, 64, 4, 2, 16, 21
    q = jnp.asarray(rng.normal(0, 1, (B, 1, Hq, D)), jnp.float32)
    kc = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    vc = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    a = kd(q, kc, vc, ln, blk_k=32)
    b = md(q, kc, vc, jnp.asarray(ln), use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ---------------------------------------------------------------------------
# ssd scan (Mamba2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (1, 64, 2, 16, 1, 16, 16), (2, 48, 4, 8, 2, 8, 16),
    (1, 40, 2, 16, 1, 32, 16),  # padded
])
def test_ssd_scan_sweep(B, S, H, P, G, N, chunk):
    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_scan_ref
    xb = jnp.asarray(rng.normal(0, 0.5, (B, S, H, P)), jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(0, 0.3, (B, S, H))), jnp.float32)
    Bm = jnp.asarray(rng.normal(0, 0.5, (B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(0, 0.5, (B, S, G, N)), jnp.float32)
    y, st = ssd_scan(xb, a, Bm, Cm, chunk=chunk)
    yr, sr = ssd_scan_ref(xb, a, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), atol=1e-4)


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (1, 37, 3, 8, 1, 8, 16),    # odd S (ragged last chunk), odd H
    (2, 50, 2, 24, 2, 12, 16),  # non-pow2 P and N
    (1, 21, 5, 8, 5, 8, 8),     # S barely above 2 chunks, G == H
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_odd_shapes(B, S, H, P, G, N, chunk, dtype):
    """Non-power-of-two sweeps vs the jnp oracle in both dtypes."""
    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_scan_ref
    xb = jnp.asarray(rng.normal(0, 0.5, (B, S, H, P)), dtype)
    a = jnp.asarray(-np.abs(rng.normal(0, 0.3, (B, S, H))), dtype)
    Bm = jnp.asarray(rng.normal(0, 0.5, (B, S, G, N)), dtype)
    Cm = jnp.asarray(rng.normal(0, 0.5, (B, S, G, N)), dtype)
    y, st = ssd_scan(xb, a, Bm, Cm, chunk=chunk)
    yr, sr = ssd_scan_ref(xb, a, Bm, Cm, chunk=chunk)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=tol)
    np.testing.assert_allclose(
        np.asarray(st, np.float32), np.asarray(sr, np.float32), atol=tol)


def test_ssd_scan_initial_state():
    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_scan_ref
    B, S, H, P, G, N, chunk = 1, 48, 2, 8, 1, 16, 16
    xb = jnp.asarray(rng.normal(0, 0.5, (B, S, H, P)), jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(0, 0.3, (B, S, H))), jnp.float32)
    Bm = jnp.asarray(rng.normal(0, 0.5, (B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(0, 0.5, (B, S, G, N)), jnp.float32)
    init = jnp.asarray(rng.normal(0, 0.5, (B, H, P, N)), jnp.float32)
    y, st = ssd_scan(xb, a, Bm, Cm, chunk=chunk, initial_state=init)
    yr, sr = ssd_scan_ref(xb, a, Bm, Cm, chunk=chunk, initial_state=init)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), atol=1e-4)


def test_ssd_scan_chunk_invariance():
    """Same result regardless of chunk size (associativity of the scan)."""
    from repro.kernels.ssd_scan.ops import ssd_scan
    B, S, H, P, G, N = 1, 64, 2, 8, 1, 8
    xb = jnp.asarray(rng.normal(0, 0.5, (B, S, H, P)), jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(0, 0.3, (B, S, H))), jnp.float32)
    Bm = jnp.asarray(rng.normal(0, 0.5, (B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(0, 0.5, (B, S, G, N)), jnp.float32)
    y16, s16 = ssd_scan(xb, a, Bm, Cm, chunk=16)
    y32, s32 = ssd_scan(xb, a, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y32), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s16), np.asarray(s32), atol=1e-4)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 64), (3, 37, 64), (2, 5, 7, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    from repro.kernels.rmsnorm.ops import rmsnorm
    from repro.kernels.rmsnorm.ref import rmsnorm_ref
    x = jnp.asarray(rng.normal(0, 1, shape), dtype)
    w = jnp.asarray(rng.normal(1, 0.1, shape[-1:]), dtype)
    out = rmsnorm(x, w, blk=16)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


def test_model_attention_uses_flash_when_enabled():
    """cfg.use_pallas routes prefill attention through the kernel and the
    result matches the XLA path."""
    from repro.configs import get_config
    from repro.models import model
    cfg = get_config("qwen3-14b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    params = model.init(cfg, jax.random.key(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)
    h1, _ = model.forward_train(params, cfg, {"tokens": tokens})
    h2, _ = model.forward_train(params, cfg.replace(use_pallas=True),
                                {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-3)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

def _paged_case(B, P, page, Hq, Hkv, D, lens, dtype=jnp.float32, seed=0):
    """Random pool + a page table whose live entries are distinct pages
    (shuffled, so physical order != logical order) and whose parked
    slots point at scratch page 0."""
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.normal(0, 1, (B, 1, Hq, D)), dtype)
    kp = jnp.asarray(r.normal(0, 1, (P, page, Hkv, D)), dtype)
    vp = jnp.asarray(r.normal(0, 1, (P, page, Hkv, D)), dtype)
    maxp = -(-max(lens) // page)
    perm = list(r.permutation(np.arange(1, P)))
    table = np.zeros((B, maxp), np.int32)
    for b, ln in enumerate(lens):
        need = -(-ln // page)
        for i in range(need):
            table[b, i] = perm.pop()
    return q, kp, vp, jnp.asarray(table), jnp.asarray(lens, jnp.int32)


def test_paged_attention_smoke():
    """One fast interpret-mode case; the full sweep is tier-2 (each
    distinct shape recompiles the Pallas interpreter)."""
    from repro.kernels.paged_attention.ops import paged_attention
    from repro.kernels.paged_attention.ref import paged_attention_ref
    B, P, page, Hq, Hkv, D = 2, 16, 8, 4, 2, 16
    q, kp, vp, table, ln = _paged_case(B, P, page, Hq, Hkv, D, [5, 23])
    out = paged_attention(q, kp, vp, table, ln)
    ref = jnp.moveaxis(paged_attention_ref(
        jnp.moveaxis(q, 2, 1), kp, vp, table, ln, scale=D ** -0.5), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.slow
@pytest.mark.parametrize("B,P,page,Hq,Hkv,D,lens", [
    (2, 16, 8, 4, 2, 16, [5, 23]),     # partial pages, GQA
    (1, 8, 16, 2, 1, 32, [48]),        # MQA, exact page multiple
    (3, 32, 4, 8, 8, 64, [1, 9, 17]),  # MHA, tiny pages
    (2, 16, 8, 6, 3, 48, [12, 31]),    # odd head counts / head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, P, page, Hq, Hkv, D, lens, dtype):
    from repro.kernels.paged_attention.ops import paged_attention
    from repro.kernels.paged_attention.ref import paged_attention_ref
    q, kp, vp, table, ln = _paged_case(B, P, page, Hq, Hkv, D, lens, dtype)
    out = paged_attention(q, kp, vp, table, ln)
    ref = jnp.moveaxis(paged_attention_ref(
        jnp.moveaxis(q, 2, 1), kp, vp, table, ln, scale=D ** -0.5), 1, 2)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.slow
@pytest.mark.parametrize("window,softcap", [(16, 0.0), (0, 30.0), (8, 50.0)])
def test_paged_attention_variants(window, softcap):
    from repro.kernels.paged_attention.ops import paged_attention
    from repro.kernels.paged_attention.ref import paged_attention_ref
    B, P, page, Hq, Hkv, D = 2, 16, 8, 4, 2, 32
    q, kp, vp, table, ln = _paged_case(B, P, page, Hq, Hkv, D, [21, 37])
    out = paged_attention(q, kp, vp, table, ln, window=window,
                          attn_softcap=softcap)
    ref = jnp.moveaxis(paged_attention_ref(
        jnp.moveaxis(q, 2, 1), kp, vp, table, ln, scale=D ** -0.5,
        window=window, softcap=softcap), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_attention_matches_dense_gather_path():
    """Pallas paged kernel agrees with the model's gather-then-dense
    paged_decode_attention (the XLA fallback the backends default to)."""
    from repro.models.attention import paged_decode_attention
    B, P, page, Hq, Hkv, D = 2, 16, 8, 4, 2, 16
    q, kp, vp, table, ln = _paged_case(B, P, page, Hq, Hkv, D, [11, 29])
    a = paged_decode_attention(q, kp, vp, table, ln, use_pallas=True)
    b = paged_decode_attention(q, kp, vp, table, ln, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
