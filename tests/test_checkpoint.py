"""Checkpoint: atomic roundtrip, GC, async writer, restore-with-cast."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_step,
                                         restore, save)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(0, 1, (4, 8)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.normal(0, 1, (3,)), jnp.bfloat16),
                   "c": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 5, t)
    assert latest_step(str(tmp_path)) == 5
    restored, step = restore(str(tmp_path), t)
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_k_gc(tmp_path):
    t = _tree()
    for s in range(6):
        save(str(tmp_path), s, t, keep=2)
    ckpts = sorted(f for f in os.listdir(tmp_path) if f.startswith("ckpt_"))
    assert len(ckpts) == 2
    assert latest_step(str(tmp_path)) == 5


def test_restore_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), 0, _tree())
    bad = _tree()
    bad["a"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError):
        restore(str(tmp_path), bad)


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=3)
    t = _tree()
    for s in range(3):
        ck.submit(s, t)
    ck.close()
    assert latest_step(str(tmp_path)) == 2
    restored, _ = restore(str(tmp_path), t)
    np.testing.assert_array_equal(np.asarray(t["a"]),
                                  np.asarray(restored["a"]))


@pytest.mark.slow
def test_restore_resume_matches_uninterrupted_training(tmp_path):
    """Fault tolerance: save mid-run, restore, continue — identical to an
    uninterrupted run (optimizer state + data determinism)."""
    from repro.configs import TrainConfig, get_config
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import DataConfig, make_batch
    from repro.models import model
    from repro.train import optim
    from repro.train.step import build_train_step

    cfg = get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    tc = TrainConfig(learning_rate=1e-3)
    shape = ShapeConfig("t", "train", 16, 2)
    dc = DataConfig()
    step_fn = jax.jit(build_train_step(cfg, tc))

    def run(params, opt, lo, hi):
        for i in range(lo, hi):
            batch = {k: jnp.asarray(v) for k, v in
                     make_batch(cfg, shape, dc, i).items()}
            params, opt, _ = step_fn(params, opt, batch)
        return params, opt

    p0 = model.init(cfg, jax.random.key(0))
    o0 = optim.init_opt_state(p0, tc)
    # uninterrupted
    pu, _ = run(p0, o0, 0, 6)
    # interrupted at 3 + resumed from checkpoint
    p3, o3 = run(p0, o0, 0, 3)
    save(str(tmp_path), 3, {"params": p3, "opt_m": o3.m, "opt_v": o3.v,
                            "count": o3.count})
    tmpl = {"params": p0, "opt_m": o0.m, "opt_v": o0.v, "count": o0.count}
    restored, step = restore(str(tmp_path), tmpl)
    opt_r = optim.OptState(m=restored["opt_m"], v=restored["opt_v"],
                           count=restored["count"])
    pr, _ = run(restored["params"], opt_r, step, 6)
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(pu), jax.tree.leaves(pr)))
    assert d < 1e-6
