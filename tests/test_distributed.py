"""Real multi-device behaviour on 8 fake CPU devices, via subprocesses
(the flag must be set before jax initializes — never in this process)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, timeout=420) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_train_step_runs_and_matches_single_device():
    res = _run("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import TrainConfig, get_config
        from repro.configs.base import ShapeConfig
        from repro.data.pipeline import DataConfig, make_batch
        from repro.launch import sharding as shd
        from repro.models import model
        from repro.train import optim
        from repro.train.step import build_train_step

        cfg = get_config("qwen3-0.6b", smoke=True).replace(
            param_dtype="float32", compute_dtype="float32", remat="none")
        tc = TrainConfig(learning_rate=1e-3)
        shape = ShapeConfig("t", "train", 16, 4)
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, shape, DataConfig(), 0).items()}
        params = model.init(cfg, jax.random.key(0))
        opt = optim.init_opt_state(params, tc)
        step = build_train_step(cfg, tc)

        # single device reference
        p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        abst = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        ps = shd.param_specs(cfg, abst, mesh, kind="train")
        zs = shd.zero1_opt_specs(ps, abst, mesh)
        opt_spec = optim.OptState(m=zs, v=zs, count=P())
        bs = shd.batch_specs(batch, mesh)
        with mesh:
            fn = jax.jit(step,
                         in_shardings=(shd.to_named(ps, mesh),
                                       shd.to_named(opt_spec, mesh),
                                       shd.to_named(bs, mesh)),
                         out_shardings=(shd.to_named(ps, mesh),
                                        shd.to_named(opt_spec, mesh),
                                        None))
            p_sh, o_sh, m_sh = fn(params, opt, batch)
        d = max(float(jnp.max(jnp.abs(a - jax.device_get(b))))
                for a, b in zip(jax.tree.leaves(p_ref),
                                jax.tree.leaves(p_sh)))
        print(json.dumps({
            "loss_ref": float(m_ref["total_loss"]),
            "loss_sh": float(m_sh["total_loss"]),
            "max_param_diff": d,
            "n_dev": jax.device_count()}))
    """)
    assert res["n_dev"] == 8
    assert abs(res["loss_ref"] - res["loss_sh"]) < 1e-4
    assert res["max_param_diff"] < 1e-4


def test_elastic_restore_onto_different_mesh(tmp_path):
    res = _run(f"""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.checkpoint import restore, save

        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                 "b": jnp.ones((8,), jnp.float32)}}
        save({str(tmp_path)!r}, 1, tree)

        # resume onto a (4,2) mesh with model-parallel sharding
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        shardings = {{
            "w": NamedSharding(mesh, P(None, "model")),
            "b": NamedSharding(mesh, P()),
        }}
        restored, step = restore({str(tmp_path)!r}, tree,
                                 shardings=shardings)
        ok = bool(jnp.all(restored["w"] == tree["w"]))
        n_shards = len(restored["w"].sharding.device_set)
        print(json.dumps({{"ok": ok, "step": step,
                           "n_shards": n_shards}}))
    """)
    assert res["ok"] and res["step"] == 1
    assert res["n_shards"] == 8


def test_shard_map_int8_allreduce_gradient_sync():
    """The explicit compressed-DP-sync path: per-shard grads are int8-
    quantized, summed with psum over int32, dequantized — 4x less traffic
    than fp32, error bounded by the quantization step."""
    res = _run("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        local_grads = jnp.asarray(rng.normal(0, 1, (8, 128)), jnp.float32)

        def sync(g):
            g = g[0]                       # local shard [128]
            amax = jnp.max(jnp.abs(g))
            # share a global scale first (tiny collective)
            gmax = jax.lax.pmax(amax, "data")
            scale = jnp.maximum(gmax, 1e-12) / 127.0
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int32)
            tot = jax.lax.psum(q, "data")  # int payload crosses the wire
            return (tot.astype(jnp.float32) * scale / 8.0)[None]

        out = shard_map(sync, mesh=mesh, in_specs=P("data", None),
                        out_specs=P("data", None))(local_grads)
        mean_true = np.asarray(local_grads).mean(0)
        err = float(np.max(np.abs(np.asarray(out)[0] - mean_true)))
        print(json.dumps({"err": err}))
    """)
    assert res["err"] < 0.05


def test_seq_parallel_decode_attention_psum():
    """Sequence-parallel flash decode: each shard attends over its local
    KV chunk, partial (numerator, denominator) psum'd — matches full
    attention. This is the SP scheme the big-GQA decode cells use."""
    res = _run("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        B, S, H, D = 2, 64, 4, 16
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(0, 1, (B, H, D)), jnp.float32)
        k = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
        v = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
        mesh = jax.make_mesh((8,), ("sp",))

        def local_attn(q, k, v):
            s = jnp.einsum("bhd,bshd->bhs", q, k) / np.sqrt(D)
            m = jnp.max(s, -1, keepdims=True)
            gm = jax.lax.pmax(m, "sp")
            p = jnp.exp(s - gm)
            num = jax.lax.psum(jnp.einsum("bhs,bshd->bhd", p, v), "sp")
            den = jax.lax.psum(jnp.sum(p, -1, keepdims=True), "sp")
            return num / den

        out = shard_map(local_attn, mesh=mesh,
                        in_specs=(P(), P(None, "sp"), P(None, "sp")),
                        out_specs=P())(q, k, v)
        s = jnp.einsum("bhd,bshd->bhs", q, k) / np.sqrt(D)
        ref = jnp.einsum("bhs,bshd->bhd", jax.nn.softmax(s, -1), v)
        err = float(jnp.max(jnp.abs(out - ref)))
        print(json.dumps({"err": err}))
    """)
    assert res["err"] < 1e-5


@pytest.mark.slow
def test_shard_map_ep_moe_matches_dense_path():
    """The optimized expert-parallel MoE (EXPERIMENTS.md P1/P2) is
    numerically exact vs the dense GSPMD path, incl. gradients, in both
    dispatch modes."""
    res = _run("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.moe import moe_ffn
        from repro.models.moe_ep import ep_mesh_context, moe_ffn_ep

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        N, d, E, f, k = 64, 32, 8, 48, 2
        x = jnp.asarray(rng.normal(0, 1, (N, d)), jnp.float32)
        ws = [jnp.asarray(rng.normal(0, 0.1, s), jnp.float32) for s in
              [(d, E), (E, d, f), (E, d, f), (E, f, d)]]
        ref = moe_ffn(x, *ws, k=k, capacity_factor=32.0)
        g_ref = jax.grad(lambda p: jnp.sum(
            moe_ffn(x, *p, k=k, capacity_factor=32.0).y ** 2))(tuple(ws))
        out = {}
        for tp in (False, True):
            with mesh, ep_mesh_context(mesh, tp_dispatch=tp):
                y = jax.jit(lambda *a: moe_ffn_ep(
                    *a, k=k, capacity_factor=32.0).y)(x, *ws)
                def loss(p):
                    with ep_mesh_context(mesh, tp_dispatch=tp):
                        return jnp.sum(moe_ffn_ep(
                            x, *p, k=k, capacity_factor=32.0).y ** 2)
                g = jax.jit(jax.grad(loss))(tuple(ws))
            out[f"y_err_tp{tp}"] = float(jnp.max(jnp.abs(ref.y - y)))
            out[f"g_err_tp{tp}"] = max(
                float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(g, g_ref))
        print(json.dumps(out))
    """)
    for k, v in res.items():
        assert v < 1e-3, (k, v)


@pytest.mark.slow
def test_pipeline_parallelism_matches_sequential():
    """GPipe-style microbatch pipeline over the 'pipe' (pod) axis equals
    sequential stage application (launch/pipeline.py)."""
    res = _run("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.pipeline import pipeline_apply

        mesh = jax.make_mesh((4, 2), ("pipe", "dp"))
        rng = np.random.default_rng(0)
        n_stages, n_micro, mb, d = 4, 6, 2, 16
        W = jnp.asarray(rng.normal(0, 0.3, (n_stages, d, d)), jnp.float32)
        b = jnp.asarray(rng.normal(0, 0.1, (n_stages, d)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (n_micro, mb, d)), jnp.float32)

        def stage(p, a):
            w, bb = p
            return jnp.tanh(a @ w + bb)

        with mesh:
            y = jax.jit(lambda p, xx: pipeline_apply(
                stage, mesh, "pipe", p, xx))((W, b), x)
        ref = x
        for s in range(n_stages):
            ref = jnp.tanh(ref @ W[s] + b[s])
        print(json.dumps({"err": float(jnp.max(jnp.abs(y - ref)))}))
    """)
    assert res["err"] < 1e-5
