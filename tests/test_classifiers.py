"""Expert-selector classifiers (paper Table 5): all from-scratch
implementations reach high accuracy on clustered feature data."""
import numpy as np
import pytest

from repro.core.classifiers import make_table5_classifiers
from repro.core.pca import PCA, Scaler, feature_importance


def _clustered_data(seed=0, n_per=30, d=10, n_classes=3, spread=0.08):
    centers = np.random.default_rng(123).uniform(0, 1, (n_classes, d))
    rng = np.random.default_rng(seed)  # noise varies, centers shared
    X, y = [], []
    for c in range(n_classes):
        X.append(centers[c] + rng.normal(0, spread, (n_per, d)))
        y += [f"class{c}"] * n_per
    return np.concatenate(X), np.asarray(y)


@pytest.mark.parametrize("name", list(make_table5_classifiers()))
def test_classifier_accuracy(name):
    X, y = _clustered_data(seed=1)
    Xt, yt = _clustered_data(seed=2)
    clf = make_table5_classifiers()[name]
    clf.fit(X, y)
    acc = clf.accuracy(Xt, yt)
    assert acc >= 0.9, (name, acc)


def test_knn_confidence_distances():
    from repro.core.classifiers import KNN
    X, y = _clustered_data(seed=3)
    knn = KNN(k=1).fit(X, y)
    labels, d_in = knn.predict_with_confidence(X[:5])
    _, d_out = knn.predict_with_confidence(np.full((1, X.shape[1]), 9.0))
    assert float(d_out[0]) > float(d_in.max()) * 5


def test_pca_variance_and_transform():
    rng = np.random.default_rng(0)
    # low-rank data + noise: a few PCs explain ~all variance
    Z = rng.normal(0, 1, (200, 3))
    W = rng.normal(0, 1, (3, 22))
    X = Z @ W + rng.normal(0, 0.01, (200, 22))
    pca = PCA.fit(X, variance=0.95)
    assert pca.components.shape[0] <= 4
    assert float(pca.explained_ratio.sum()) > 0.9
    T = pca.transform(X)
    assert T.shape == (200, pca.components.shape[0])


def test_scaler_clips_unseen_range():
    X = np.asarray([[0.0, 10.0], [1.0, 20.0]])
    s = Scaler.fit(X)
    out = s.transform(np.asarray([[2.0, 40.0]]))
    assert np.all(out <= 1.5)


def test_feature_importance_finds_informative_dims():
    rng = np.random.default_rng(0)
    n = 300
    X = rng.normal(0, 0.01, (n, 8))
    X[:, 2] = rng.normal(0, 1.0, n)   # dominant feature
    X[:, 5] = rng.normal(0, 0.7, n)
    pca = PCA.fit(X, n_components=3)
    imp = feature_importance(pca)
    assert set(np.argsort(imp)[-2:]) == {2, 5}
