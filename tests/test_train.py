"""Optimizer / loss / step / compression / data-pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_config, smoke_shape
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, make_batch
from repro.models import model
from repro.train import optim
from repro.train.compression import (compress_grads_ef, dequantize_int8,
                                     init_error_buffer, quantize_int8)
from repro.train.loss import lm_loss
from repro.train.step import build_train_step


def test_adamw_minimizes_quadratic():
    tc = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=200,
                     weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = optim.init_opt_state(params, tc)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = optim.adamw_update(params, grads, state, tc)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_cosine_schedule_shape():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(optim.cosine_schedule(tc, jnp.asarray(s)))
           for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9          # peak after warmup
    assert lrs[-1] < lrs[1]                   # decays
    assert lrs[-1] >= 0.1 * 1e-3 - 1e-12      # floor at 10%


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(optim.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 1.0


def test_train_step_reduces_loss_smoke():
    cfg = get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    tc = TrainConfig(learning_rate=5e-3, warmup_steps=5, total_steps=120)
    shape = ShapeConfig("t", "train", 32, 8)
    dc = DataConfig(kind="lm_synthetic")
    params = model.init(cfg, jax.random.key(0))
    opt = optim.init_opt_state(params, tc)
    step = jax.jit(build_train_step(cfg, tc))
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, shape, dc, i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["total_loss"]))
    assert losses[-1] < losses[0] * 0.75, losses[::6]


@pytest.mark.slow
def test_microbatch_accumulation_matches_full_batch():
    cfg = get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32", remat="none")
    shape = ShapeConfig("t", "train", 16, 4)
    dc = DataConfig()
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, shape, dc, 0).items()}
    params = model.init(cfg, jax.random.key(0))
    tc_full = TrainConfig(learning_rate=1e-3)
    tc_micro = TrainConfig(learning_rate=1e-3, microbatch=2)
    opt = optim.init_opt_state(params, tc_full)
    p1, _, m1 = build_train_step(cfg, tc_full)(params, opt, batch)
    p2, _, m2 = build_train_step(cfg, tc_micro)(params, opt, batch)
    np.testing.assert_allclose(float(m1["total_loss"]),
                               float(m2["total_loss"]), rtol=1e-5)
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 1e-5


def test_vocab_loss_mask():
    cfg = get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    params = model.init(cfg, jax.random.key(0))
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 8)), jnp.int32)
    h, _ = model.forward_train(params, cfg, {"tokens": tokens})
    labels = tokens
    full, _ = lm_loss(params, cfg, h, labels)
    masked, _ = lm_loss(params, cfg, h, labels,
                        jnp.zeros((2, 8)).at[:, :4].set(1.0))
    half, _ = lm_loss(params, cfg, h[:, :4], labels[:, :4])
    np.testing.assert_allclose(float(masked), float(half), rtol=1e-6)
    assert float(full) != float(masked)


def test_seq_chunked_loss_equivalence():
    cfg = get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    params = model.init(cfg, jax.random.key(0))
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 16)), jnp.int32)
    h, _ = model.forward_train(params, cfg, {"tokens": tokens})
    l1, _ = lm_loss(params, cfg, h, tokens, seq_chunks=1)
    l4, _ = lm_loss(params, cfg, h, tokens, seq_chunks=4)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-6)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_quantization_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (128,)), jnp.float32)
    q, s = quantize_int8(x)
    err = float(jnp.max(jnp.abs(dequantize_int8(q, s) - x)))
    assert err <= float(s) * 0.5 + 1e-7


def test_error_feedback_unbiased_over_time():
    """Accumulated EF-compressed gradients converge to the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32)
    grads = {"w": g_true}
    buf = init_error_buffer(grads)
    total = jnp.zeros_like(g_true)
    for _ in range(50):
        dec, buf = compress_grads_ef(grads, buf)
        total = total + dec["w"]
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g_true),
                               atol=1e-2)


@pytest.mark.slow
def test_compressed_training_converges():
    cfg = get_config("qwen3-0.6b", smoke=True).replace(
        param_dtype="float32", compute_dtype="float32")
    tc = TrainConfig(learning_rate=5e-3, warmup_steps=5, total_steps=120,
                     grad_compression="int8_ef")
    from repro.train.step import build_train_step_compressed
    shape = ShapeConfig("t", "train", 32, 8)
    dc = DataConfig(kind="lm_synthetic")
    params = model.init(cfg, jax.random.key(0))
    opt = optim.init_opt_state(params, tc)
    ebuf = init_error_buffer(params)
    step = jax.jit(build_train_step_compressed(cfg, tc))
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, shape, dc, i).items()}
        params, opt, ebuf, m = step(params, opt, ebuf, batch)
        losses.append(float(m["total_loss"]))
    assert losses[-1] < losses[0] * 0.75


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_sharding():
    cfg = get_config("qwen3-0.6b", smoke=True)
    shape = ShapeConfig("t", "train", 16, 8)
    dc = DataConfig()
    b1 = make_batch(cfg, shape, dc, step=3)
    b2 = make_batch(cfg, shape, dc, step=3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    s0 = make_batch(cfg, shape, dc, step=3, shard=0, num_shards=2)
    s1 = make_batch(cfg, shape, dc, step=3, shard=1, num_shards=2)
    assert s0["tokens"].shape[0] == 4
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_data_modality_batches():
    shape = ShapeConfig("t", "train", 16, 2)
    vlm = get_config("pixtral-12b", smoke=True)
    b = make_batch(vlm, shape, DataConfig(), 0)
    assert b["patch_embeds"].shape == (2, 4, vlm.d_model)
    assert b["tokens"].shape == (2, 12)
    enc = get_config("whisper-large-v3", smoke=True)
    b = make_batch(enc, shape, DataConfig(), 0)
    assert b["enc_embeds"].shape == (2, 8, enc.d_model)
    assert b["tokens"].shape == (2, 8)
