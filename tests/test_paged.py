"""Paged KV backends: allocator invariants, chunked prefill, the
dense-shim accounting fixes, and paged-vs-dense acceptance.

Fast tier drives the virtual-time backends (PagedSimBackend /
DenseSimBackend); @slow covers the real jax path, including the
paged-vs-dense token-stream equivalence golden.
"""
import numpy as np
import pytest

from repro.sched.resources import ResourceVector
from repro.serve import (DenseSimBackend, Engine, PagedSimBackend,
                         Request, ServingDemand, pages_for)
from repro.serve.backends import _shrink_bucket
from repro.serve.paged import PageAllocator


def make_requests(n, seed=0, rate=20.0, prompt=(8, 32), new=(8, 40)):
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [Request(rid=i, prompt_len=int(rng.integers(*prompt)),
                    max_new_tokens=int(rng.integers(*new)),
                    arrival=float(t[i])) for i in range(n)]


# --- PageAllocator ----------------------------------------------------------

def test_page_allocator_ledgers():
    a = PageAllocator(num_pages=9, page_size=4)
    assert a.usable_pages == 8        # page 0 is scratch
    a.reserve(1, 3)
    a.reserve(2, 5)
    assert not a.can_reserve(1)       # pool fully reserved
    with pytest.raises(RuntimeError):
        a.reserve(3, 1)
    with pytest.raises(RuntimeError):
        a.reserve(1, 1)               # double reservation
    assert a.grow_to(1, 5) == a.pages_of(1)
    assert len(a.pages_of(1)) == pages_for(5, 4) == 2
    assert 0 not in a.pages_of(1)     # scratch never handed out
    a.grow_to(2, 17)
    assert a.allocated_pages == 2 + 5
    assert a.free_pages == 8 - 7
    a.release(1)
    assert a.allocated_pages == 5 and a.can_reserve(3)
    a.release(2)
    assert a.free_pages == a.usable_pages == 8
    assert a.reserved_pages == 0


def test_page_allocator_growth_never_exceeds_reservation():
    a = PageAllocator(num_pages=5, page_size=2)
    a.reserve(7, 2)
    with pytest.raises(AssertionError):
        a.grow_to(7, 5)               # 3 pages > the 2 reserved


def test_page_allocator_rejects_degenerate_pools():
    with pytest.raises(ValueError):
        PageAllocator(num_pages=1, page_size=4)
    with pytest.raises(ValueError):
        PageAllocator(num_pages=8, page_size=0)


# --- conservation: allocated pages == sum(ceil(live / page)) every step ----

class _CheckedPaged(PagedSimBackend):
    def decode(self, running):
        cost = super().decode(running)
        live = sum(pages_for(self._live_tokens(r), self.page_size)
                   for r in self._slots)
        assert live == self.alloc.allocated_pages, \
            (live, self.alloc.allocated_pages)
        assert self.alloc.allocated_pages + self.alloc.free_pages \
            == self.alloc.usable_pages
        return cost


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_page_conservation_invariant_every_step(seed):
    """Allocated pages exactly cover live tokens at every decode step —
    no leaks, no double-allocation — and the pool drains to empty."""
    demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                           host_ram_per_req_gb=0.01, page_size=8)
    budget = ResourceVector(hbm=0.5 + 2e-4 * 72 * 3.0,
                            host_ram=0.01 * 6.0)
    be = _CheckedPaged(num_pages=1 + 16 * pages_for(80, 8), page_size=8,
                       prefill_chunk=8)
    eng = Engine(make_requests(24, seed=seed), demand, budget, be,
                 max_batch=16)
    s = eng.run()
    assert s["completed"] == 24
    assert be.alloc.allocated_pages == 0
    assert be.alloc.reserved_pages == 0
    assert be.alloc.free_pages == be.alloc.usable_pages
    for dec in eng.metrics.steps:
        assert dec.booked.fits(dec.budget) or dec.forced


def test_paged_joinable_is_position_independent():
    """The lifted constraint: a prompt LONGER than every running context
    can join mid-stream (dense joinable demands prefill <= position)."""
    be = PagedSimBackend(num_pages=1 + 40, page_size=4, prefill_chunk=8)
    be.join([Request(rid=0, prompt_len=6, max_new_tokens=4)], 0.0)
    assert not be.empty and be.position == 0
    long_req = Request(rid=1, prompt_len=50, max_new_tokens=8)
    assert be.joinable(long_req)      # pages fit; position irrelevant
    dense = DenseSimBackend(max_len=80, sync=8)
    dense.join([Request(rid=2, prompt_len=6, max_new_tokens=4)], 0.0)
    assert not dense.joinable(long_req)   # prefill 50 > position


def test_paged_filter_joinable_is_cumulative():
    """The pool is a collective constraint: each accepted candidate
    shrinks what the next can reserve (prefix admission stays safe)."""
    be = PagedSimBackend(num_pages=1 + 10, page_size=4, prefill_chunk=8)
    reqs = [Request(rid=i, prompt_len=12, max_new_tokens=4)
            for i in range(4)]                 # 4 pages worst-case each
    picked = be.filter_joinable(reqs)
    assert [r.rid for r in picked] == [0, 1]   # 2 fit, not 4
    assert all(be.joinable(r) for r in reqs)   # individually all fit


# --- chunked prefill --------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 3])
def test_chunked_prefill_cuts_short_request_ttft(seed):
    """Head-of-line blocking: short requests arriving around a few very
    long prompts see lower TTFT when prefill runs in chunks interleaved
    with decode than when each join stalls on the full prompt."""
    def bimodal(seed):
        rng = np.random.default_rng(seed)
        t = np.cumsum(rng.exponential(1.0 / 200.0, size=16))
        longs = set(int(x) for x in rng.choice(16, 3, replace=False))
        reqs = [Request(rid=i,
                        prompt_len=int(rng.integers(300, 500))
                        if i in longs else int(rng.integers(4, 12)),
                        max_new_tokens=int(rng.integers(4, 12)),
                        arrival=float(t[i])) for i in range(16)]
        return reqs, longs

    def short_ttft(chunk):
        reqs, longs = bimodal(seed)
        demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                               page_size=8)
        be = PagedSimBackend(num_pages=1 + 8 * 64, page_size=8,
                             prefill_chunk=chunk)
        eng = Engine(reqs, demand, ResourceVector(hbm=100.0), be,
                     max_batch=8)
        s = eng.run()
        assert s["completed"] == 16
        return float(np.mean([r.first_token_t - r.arrival
                              for r in eng.requests
                              if r.rid not in longs]))

    assert short_ttft(16) < short_ttft(10 ** 6)


def test_paged_token_streams_match_dense_sim():
    """Same deterministic synthesis, so every request's stream is
    identical across backends — scheduling changes, content does not."""
    def run(be):
        demand = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4)
        eng = Engine(make_requests(12, seed=4), demand,
                     ResourceVector(hbm=100.0), be, max_batch=8)
        assert eng.run()["completed"] == 12
        return {r.rid: list(r.tokens) for r in eng.requests}

    paged = run(PagedSimBackend(num_pages=1 + 8 * 10, page_size=8,
                                prefill_chunk=8))
    dense = run(DenseSimBackend(max_len=80, sync=8))
    assert paged == dense


# --- paged-vs-dense acceptance (the ISSUE bar, sim tier) -------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_paged_beats_dense_on_waste(seed):
    """Contended cell: paged residency waste strictly below dense (which
    holds the full bucket * max_len grid), goodput no worse."""
    demand_p = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                             page_size=8)
    demand_d = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4)
    budget = ResourceVector(hbm=0.5 + 2e-4 * 72 * 3.0)
    paged = PagedSimBackend(num_pages=1 + 16 * pages_for(80, 8),
                            page_size=8, prefill_chunk=8)
    dense = DenseSimBackend(max_len=80, sync=8)
    ep = Engine(make_requests(24, seed=seed), demand_p, budget, paged,
                max_batch=16)
    sp = ep.run()
    ed = Engine(make_requests(24, seed=seed), demand_d, budget, dense,
                max_batch=16)
    sd = ed.run()
    assert sp["completed"] == sd["completed"] == 24
    assert paged.waste_ratio() < dense.waste_ratio()
    assert sp["goodput_tok_s"] >= sd["goodput_tok_s"] * 0.95


# --- S1: dense join cost charges the padded position -----------------------

def test_dense_sim_join_cost_charges_padded_position():
    be = DenseSimBackend(max_len=48, sync=8)
    r0 = Request(rid=0, prompt_len=5, max_new_tokens=30)
    cost = be.join([r0], 0.0)
    assert be.position == 8           # 5 rounds up to the sync stride
    assert cost == pytest.approx(be._timer.t_prefill_per_token * 8)
    r1 = Request(rid=1, prompt_len=3, max_new_tokens=30)
    cost = be.join([r1], 0.0)         # mid-stream: re-prefills to pos
    assert cost == pytest.approx(be._timer.t_prefill_per_token * 8)


# --- S2: bucket shrink hysteresis ------------------------------------------

def test_shrink_bucket_hysteresis_pure():
    # above/equal target: no shrink, streak resets
    assert _shrink_bucket(8, 8, 2, 3) == (8, 0)
    assert _shrink_bucket(8, 5, 2, 3) == (8, 0)   # bucket(5) == 8
    # below target: streak builds, shrink only at patience
    assert _shrink_bucket(8, 4, 0, 3) == (8, 1)
    assert _shrink_bucket(8, 4, 1, 3) == (8, 2)
    assert _shrink_bucket(8, 4, 2, 3) == (4, 0)
    # patience=1 shrinks immediately (the old behaviour)
    assert _shrink_bucket(8, 4, 0, 1) == (4, 0)
    # shrink lands on the CURRENT bucket, not one step down
    assert _shrink_bucket(16, 2, 1, 2) == (2, 0)


def test_dense_sim_cap_survives_join_finish_oscillation():
    """A batch oscillating on a power-of-two edge must keep ONE cache
    shape under hysteresis (patience > churn period)."""
    be = DenseSimBackend(max_len=64, sync=1, shrink_patience=4)
    rs = [Request(rid=i, prompt_len=4, max_new_tokens=50)
          for i in range(6)]
    be.join(rs[:5], 0.0)              # cap -> 8
    caps = {be.kv_resident_tokens() // be.max_len}
    for _ in range(6):                # finish one, admit one, repeat
        be.remove([be._slots[-1]])
        caps.add(be.kv_resident_tokens() // be.max_len)
        nxt = Request(rid=100 + _, prompt_len=4, max_new_tokens=50)
        assert be.joinable(nxt)
        be.join([nxt], 0.0)
        caps.add(be.kv_resident_tokens() // be.max_len)
    assert caps == {8}                # zero re-bucketing under churn


# --- S3: reserved-axis leakage rejected at construction --------------------

def test_serving_demand_rejects_reserved_extra_axes():
    with pytest.raises(ValueError, match="reserved"):
        ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                      extra_axes={"hbm": 99.0})
    with pytest.raises(ValueError, match="reserved"):
        ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                      extra_axes={"host_ram": 1.0, "net": 0.1})
    # non-reserved side-cars still pass through
    sd = ServingDemand(weights_gb=0.5, kv_gb_per_token=2e-4,
                       extra_axes={"net": 0.1})
    assert sd.per_request_axes()["net"] == pytest.approx(0.1)


def test_poisoned_estimate_raises_not_overwrites():
    """Regression: a (buggy) estimator leaking an 'hbm' curve used to
    silently overwrite the computed KV term in request_vector; now the
    construction path raises."""
    from types import SimpleNamespace
    fn = SimpleNamespace(family="affine", m=0.5, b=0.2)
    dm = SimpleNamespace(primary_fn=fn, primary_axis="kv",
                         curves={"hbm": SimpleNamespace(b=123.0)})
    with pytest.raises(ValueError, match="reserved"):
        ServingDemand.from_demand_model(dm, max_len=40)


# --- page-quantized demand --------------------------------------------------

def test_demand_books_page_quantized_kv():
    sd = ServingDemand(weights_gb=0.0, kv_gb_per_token=1e-3,
                       page_size=16)
    assert sd.kv_gb(1) == pytest.approx(1e-3 * 16)
    assert sd.kv_gb(16) == pytest.approx(1e-3 * 16)
    assert sd.kv_gb(17) == pytest.approx(1e-3 * 32)
    # page_size=1 (default) stays the exact dense-token model
    exact = ServingDemand(weights_gb=0.0, kv_gb_per_token=1e-3)
    assert exact.kv_gb(17) == pytest.approx(1e-3 * 17)
    req = Request(rid=0, prompt_len=5, max_new_tokens=4)
    vec = sd.request_vector(req)
    assert vec["hbm"] == pytest.approx(1e-3 * 16)
    with pytest.raises(ValueError):
        ServingDemand(weights_gb=0.0, kv_gb_per_token=1e-3, page_size=0)


def test_model_target_carries_page_size():
    from repro.sched import ModelTarget
    t = ModelTarget(object(), 32, page_size=8)
    assert t.page_size == 8
    assert ModelTarget(object(), 32).page_size == 1


# --- the real jax path ------------------------------------------------------

def _smoke_cfg():
    from repro.configs import get_config
    return get_config("qwen3-0.6b", smoke=True)


@pytest.mark.slow
def test_paged_jax_matches_dense_jax_token_streams():
    """The migration golden: equal prompt lengths + sync=1 +
    simultaneous arrival make the dense shim prefill with no left-pad,
    so the paged backend (chunked prefill + per-request lengths over the
    page pool) must reproduce its greedy streams bit-for-bit."""
    from repro.serve import JaxBackend, PagedJaxBackend
    cfg = _smoke_cfg()
    rng = np.random.default_rng(7)
    prompts = [list(rng.integers(3, cfg.vocab_size, 11))
               for _ in range(4)]

    def reqs():
        return [Request(rid=i, prompt_len=11, max_new_tokens=6,
                        arrival=0.0, prompt=list(prompts[i]))
                for i in range(4)]

    demand = ServingDemand(weights_gb=0.01, kv_gb_per_token=1e-6)
    budget = ResourceVector(hbm=100.0)

    def run(be):
        eng = Engine(reqs(), demand, budget, be, max_batch=4)
        assert eng.run()["completed"] == 4
        return {r.rid: list(r.tokens) for r in eng.requests}

    dense = run(JaxBackend(cfg, max_len=32, sync=1, seed=0))
    paged = run(PagedJaxBackend(cfg, num_pages=1 + 4 * 5, page_size=4,
                                prefill_chunk=4, seed=0))
    assert paged == dense


@pytest.mark.slow
def test_paged_jax_preemption_and_staggered_arrivals():
    """Tight budget on the real paged backend: mid-stream joins at
    arbitrary progress, eviction + full-context recompute on rejoin,
    exact token counts, pool drained at the end."""
    from repro.serve import PagedJaxBackend
    cfg = _smoke_cfg()
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt_len=int(rng.integers(4, 20)),
                    max_new_tokens=int(rng.integers(4, 10)),
                    arrival=float(i) * 1e-3) for i in range(8)]
    sd = ServingDemand(weights_gb=0.01, kv_gb_per_token=1e-4,
                       page_size=4)
    budget = ResourceVector(hbm=0.01 + 1e-4 * 32 * 2.0)
    be = PagedJaxBackend(cfg, num_pages=1 + 8 * pages_for(32, 4),
                         page_size=4, prefill_chunk=8, seed=1)
    eng = Engine(reqs, sd, budget, be, max_batch=8)
    s = eng.run()
    assert s["completed"] == 8
    for r in eng.requests:
        assert len(r.tokens) == r.max_new_tokens
        assert all(isinstance(t, int) for t in r.tokens)
    assert be.alloc.allocated_pages == 0
    assert be.alloc.reserved_pages == 0


@pytest.mark.slow
def test_jax_dense_join_cost_golden():
    """S1 pin: the dense shim charges prefill at the PADDED position it
    actually computes (every row prefills to self._pos), not the raw
    prompt length."""
    from repro.serve import JaxBackend
    be = JaxBackend(_smoke_cfg(), max_len=48, sync=8, seed=0)
    cost = be.join([Request(rid=0, prompt_len=5, max_new_tokens=30)],
                   0.0)
    assert be._pos == 8
    assert cost == pytest.approx(be._timer.t_prefill_per_token * 8)
    cost = be.join([Request(rid=1, prompt_len=3, max_new_tokens=30)],
                   0.0)
    assert cost == pytest.approx(be._timer.t_prefill_per_token * 8)


@pytest.mark.slow
def test_jax_dense_cache_shape_hysteresis():
    """S2 pin: removals only re-bucket the batch axis down after
    `shrink_patience` consecutive shrink-eligible removals."""
    from repro.serve import JaxBackend
    be = JaxBackend(_smoke_cfg(), max_len=48, sync=8, seed=0,
                    shrink_patience=3)
    rs = [Request(rid=10 + i, prompt_len=4, max_new_tokens=40)
          for i in range(5)]
    be.join(rs, 0.0)
    caps = [be._last.shape[0]]
    for r in rs[:4]:
        be.remove([r])
        caps.append(be._last.shape[0])
    # cap 8 holds through 2 removals (streak < patience), shrinks on
    # the 3rd, then holds again
    assert caps == [8, 8, 8, 2, 2]
