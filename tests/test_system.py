"""End-to-end behaviour of the paper's system: mixture-of-experts memory
prediction driving memory-aware co-location."""
import numpy as np
import pytest

from repro.core import (ANNPredictor, MoEPredictor, SimConfig,
                        make_policies, spark_sim_suite, training_apps)
from repro.core.metrics import run_scenario


@pytest.fixture(scope="module")
def suite():
    apps = spark_sim_suite()
    train = training_apps(apps)
    moe = MoEPredictor().fit(train)
    ann = ANNPredictor().fit(train)
    return apps, moe, ann


def test_suite_composition(suite):
    apps, _, _ = suite
    assert len(apps) == 44
    assert len(training_apps(apps)) == 16
    fams = {a.family for a in apps}
    assert fams == {"power", "exp_saturation", "log"}


def test_expert_selection_accuracy(suite):
    """Paper Table 5: KNN selector ~97% accurate; clusters are tight."""
    apps, moe, _ = suite
    correct = sum(moe.select_family(a.features)[0] == a.family
                  for a in apps)
    assert correct / len(apps) >= 0.9


def test_memory_prediction_error_under_5pct(suite):
    """Paper Section 6.9: average prediction error ~5%."""
    apps, moe, _ = suite
    rng = np.random.default_rng(0)
    errs = []
    for app in apps:
        fn, _ = moe.predict_function(app, 1000.0, rng)
        t = app.true_fn(1000.0)
        errs.append(abs(fn(1000.0) - t) / t)
    assert float(np.mean(errs)) < 0.05


def test_policy_ordering_matches_paper(suite):
    """Fig. 6: ours > pairwise/online on STP; oracle bounds ours."""
    apps, moe, ann = suite
    pols = make_policies(moe, ann)
    stp = {}
    for name, pol in pols.items():
        r = run_scenario(apps, lambda mix, p=pol: p, n_jobs=13, n_mixes=4,
                         seed=7)
        stp[name] = r.stp_gmean
    assert stp["oracle"] >= stp["ours"] * 0.98
    assert stp["ours"] > stp["pairwise"]
    assert stp["ours"] > stp["online"]
    assert stp["ours"] >= stp["quasar"] * 0.99
    # ours achieves a large fraction of oracle (paper: 83.9%)
    assert stp["ours"] / stp["oracle"] > 0.7


def test_co_location_beats_isolation(suite):
    """STP > 1 means co-location outperforms one-by-one execution."""
    apps, moe, _ = suite
    from repro.core.simulator import OursPolicy
    r = run_scenario(apps, lambda mix: OursPolicy(moe), n_jobs=7,
                     n_mixes=4, seed=3)
    assert r.stp_gmean > 2.0
    assert r.antt_reduction_mean > 0.0


def test_fault_tolerance_jobs_complete(suite):
    """Host failures re-queue non-checkpointed work; everything finishes."""
    apps, moe, _ = suite
    from repro.core.metrics import make_mix
    from repro.core.simulator import OursPolicy, Simulator
    rng = np.random.default_rng(1)
    jobs = make_mix(apps, 9, rng)
    cfg = SimConfig(failures=True, host_mtbf_s=400.0, repair_time_s=50.0,
                    straggler_prob=0.1)
    sim = Simulator(jobs, OursPolicy(moe), cfg, seed=1)
    out = sim.run()
    assert all(c < cfg.max_sim_time for c in out["c_cl"])
    # failures cost time but the schedule still beats serial isolation
    assert out["stp"] > 1.0


def test_simulator_determinism(suite):
    apps, moe, _ = suite
    from repro.core.simulator import OursPolicy
    r1 = run_scenario(apps, lambda m: OursPolicy(moe), n_jobs=6, n_mixes=2,
                      seed=5)
    r2 = run_scenario(apps, lambda m: OursPolicy(moe), n_jobs=6, n_mixes=2,
                      seed=5)
    assert r1.stp_gmean == r2.stp_gmean
    assert r1.antt_gmean == r2.antt_gmean


def test_memory_never_overclaimed(suite):
    """Scheduler invariant: booked memory never exceeds capacity."""
    apps, moe, _ = suite
    from repro.core.metrics import make_mix
    from repro.core.simulator import OursPolicy, Simulator
    rng = np.random.default_rng(2)
    jobs = make_mix(apps, 11, rng)
    cfg = SimConfig()
    sim = Simulator(jobs, OursPolicy(moe), cfg, seed=2)
    orig = sim._spawn

    def spy(job, host, items, mt, mc, delay=0.0):
        e = orig(job, host, items, mt, mc, delay)
        assert host.mem_claimed <= cfg.host_mem_gb + 1e-6
        return e

    sim._spawn = spy
    sim.run()


def test_stp_bounded_by_job_count(suite):
    apps, moe, _ = suite
    from repro.core.simulator import OursPolicy
    r = run_scenario(apps, lambda m: OursPolicy(moe), n_jobs=6, n_mixes=3,
                     seed=11)
    assert r.stp_gmean <= 6.0 + 1e-9


def test_knn_confidence_fallback(suite):
    """An app far from every training cluster triggers the conservative
    path (paper Section 6.9: distance = soundness guarantee)."""
    apps, moe, _ = suite
    alien = np.full(len(apps[0].features), 5.0)  # far outside [0,1]
    fam, dist, confident = moe.select_family(alien)
    assert not confident


def test_tpu_jobs_universe():
    """The beyond-paper universe: assigned cells as schedulable jobs with
    the affine expert the paper's library needs extending with."""
    from repro.core import tpu_jobs_suite
    jobs = tpu_jobs_suite()
    assert len(jobs) == 32  # 10 archs x 3 shapes + 2 long_500k
    assert all(j.family == "affine" for j in jobs)
    kimi = [j for j in jobs if j.name.startswith("kimi") and
            "train" in j.name][0]
    assert kimi.true_fn(0.0) > 1000  # ~2 TB of weights in GB
