"""The unified DemandEstimator API (repro/sched/estimator.py).

Three layers of coverage:

* registry round-trip + protocol surface for every implementation;
* per-implementation invariants: monotone demand curves, inverse
  consistency (the admitted units' demand fits the budget that admitted
  them), predicted side-car curves close to ground truth;
* golden back-compat pins: the deprecated per-call shims — predictor
  wrapping, ``DemandModel.from_model_config``, and the simulator's
  scalar path — stay bit-identical to the PR 2/3 behaviour.
"""
import copy
import warnings

import numpy as np
import pytest

from repro.core import MoEPredictor, spark_sim_suite, training_apps
from repro.core.experts import MemoryFunction
from repro.core.predictor import (OraclePredictor, UnifiedFamilyPredictor,
                                  calibration_points)
from repro.core.simulator import OursPolicy, SimConfig, Simulator
from repro.sched import (DemandEstimate, DemandEstimator, JobTarget,
                         ModelTarget, OnlineRefresher, ResourceVector,
                         available_estimators, get_estimator,
                         register_estimator, resolve_estimator,
                         wrap_predictor)
from repro.sched.estimator import _REGISTRY, PredictorEstimator

JOB_ESTIMATORS = ("moe", "oracle", "single-family", "conservative")


@pytest.fixture(scope="module")
def suite():
    apps = spark_sim_suite()
    moe = MoEPredictor().fit(training_apps(apps))
    return apps, moe


def _est(name, moe):
    return get_estimator(name, predictor=moe)


# --- registry ---------------------------------------------------------------

def test_registry_round_trip(suite):
    apps, moe = suite
    assert set(available_estimators()) >= {
        "moe", "oracle", "single-family", "ann", "conservative",
        "kv-growth"}
    for name in JOB_ESTIMATORS:
        est = _est(name, moe)
        assert isinstance(est, DemandEstimator)
        assert est.name == name
        de = est.estimate(JobTarget(apps[0], 30.0),
                          rng=np.random.default_rng(0))
        assert isinstance(de, DemandEstimate)
        assert de.primary_fn is not None
        assert set(de.confidence) == set(de.model.curves.axes
                                         if hasattr(de.model.curves,
                                                    "axes")
                                         else de.model.curves)
    with pytest.raises(KeyError):
        get_estimator("no-such-estimator")
    with pytest.raises(ValueError):
        get_estimator("moe")          # needs a fitted predictor
    with pytest.raises(ValueError):
        get_estimator("ann")          # needs a fitted ANNPredictor


def test_register_estimator_extension_point(suite):
    apps, _ = suite

    @register_estimator("_test-flat")
    class _Flat(DemandEstimator):
        def __init__(self, predictor=None):
            pass

        def estimate(self, target, probes=None, *, rng=None):
            from repro.sched.resources import DemandModel
            fn = MemoryFunction("affine", 1.0, 0.0)
            return DemandEstimate(
                DemandModel({target.primary_axis: fn},
                            primary_axis=target.primary_axis),
                {target.primary_axis: 1.0}, False, {})
    try:
        assert "_test-flat" in available_estimators()
        de = get_estimator("_test-flat").estimate(JobTarget(apps[0], 1.0))
        assert de.primary_fn(5.0) == 1.0
    finally:
        _REGISTRY.pop("_test-flat", None)


def test_wrap_predictor_mapping(suite):
    _, moe = suite
    assert wrap_predictor(moe).name == "moe"
    assert wrap_predictor(OraclePredictor()).name == "oracle"
    sf = wrap_predictor(UnifiedFamilyPredictor("log"))
    assert sf.name == "single-family" and sf.family == "log"
    assert wrap_predictor(None) is None
    est = _est("moe", moe)
    assert wrap_predictor(est) is est            # instances pass through
    assert resolve_estimator(est) is est
    assert resolve_estimator("oracle").name == "oracle"
    assert resolve_estimator(None, predictor=moe).name == "moe"

    class _Duck:
        def predict_function(self, app, items, rng):
            return MemoryFunction("affine", 0.0, 1.0), {}
    assert isinstance(wrap_predictor(_Duck()), PredictorEstimator)
    with pytest.raises(TypeError):
        wrap_predictor(object())


# --- golden shims: bit-identical to the pre-estimator paths ----------------

def test_moe_estimate_bit_identical_to_predict_function(suite):
    """The moe estimator's primary curve IS predict_function: same RNG
    draws, same family selection, same calibrated (m, b), same info."""
    apps, moe = suite
    for i in (0, 7, 19, 30):
        fn, info = moe.predict_function(apps[i], 1000.0,
                                        np.random.default_rng(i))
        de = _est("moe", moe).estimate(JobTarget(apps[i], 1000.0),
                                       rng=np.random.default_rng(i))
        assert de.primary_fn.family == fn.family
        assert (de.primary_fn.m, de.primary_fn.b) == (fn.m, fn.b)
        assert de.info == info
        assert de.conservative == (not info["confident"])


def test_single_family_bit_identical_to_unified_predictor(suite):
    apps, _ = suite
    pred = UnifiedFamilyPredictor("exp_saturation")
    fn, _ = pred.predict_function(apps[3], 500.0,
                                  np.random.default_rng(2))
    de = get_estimator("single-family",
                       family="exp_saturation").estimate(
        JobTarget(apps[3], 500.0), rng=np.random.default_rng(2))
    assert (de.primary_fn.family, de.primary_fn.m, de.primary_fn.b) \
        == (fn.family, fn.m, fn.b)


def test_simulator_default_equals_explicit_moe(suite):
    """SimConfig.estimator='moe' through the registry is bit-identical
    to the default predictor wrap (the pre-redesign path)."""
    apps, moe = suite
    jobs = [(apps[i], 30.0) for i in (0, 5, 11, 17)]
    base = Simulator(jobs, OursPolicy(moe), SimConfig(n_hosts=4),
                     seed=1).run()
    via_cfg = Simulator(jobs, OursPolicy(moe),
                        SimConfig(n_hosts=4, estimator="moe"),
                        seed=1).run()
    via_ctor = Simulator(jobs, OursPolicy(estimator=_est("moe", moe)),
                         SimConfig(n_hosts=4), seed=1).run()
    for r in (via_cfg, via_ctor):
        assert r["stp"] == base["stp"]
        assert r["antt"] == base["antt"]
        assert r["binding_axes"] == base["binding_axes"]


def test_simulator_conservative_estimator_halves_admissions(suite):
    """The conservative registry entry actually changes scheduling:
    every job is flagged conservative -> memory budgets halve."""
    apps, moe = suite
    # large inputs so memory (not the chunk cap) binds admissions —
    # halved budgets then genuinely change the schedule
    jobs = [(apps[i], 1000.0) for i in (0, 5, 11, 17)]
    base = Simulator(jobs, OursPolicy(moe), SimConfig(n_hosts=4),
                     seed=1).run()
    cons = Simulator(jobs, OursPolicy(moe),
                     SimConfig(n_hosts=4, estimator="conservative"),
                     seed=1).run()
    assert cons["stp"] != base["stp"]
    sim = Simulator(jobs, OursPolicy(moe),
                    SimConfig(n_hosts=4, estimator="conservative"),
                    seed=1)
    sim.run()
    assert all(j.conservative for j in sim.jobs)


def test_from_model_config_shim_matches_kv_growth_estimator():
    from repro.configs import get_config
    from repro.sched.resources import DemandModel
    cfg = get_config("qwen3-0.6b", smoke=True)
    de = get_estimator("kv-growth").estimate(
        ModelTarget(cfg, 48, host_ram_per_req_gb=0.02))
    with pytest.warns(DeprecationWarning):
        dm = DemandModel.from_model_config(cfg, 48,
                                           host_ram_per_req_gb=0.02)
    assert (dm.primary_fn.m, dm.primary_fn.b) \
        == (de.primary_fn.m, de.primary_fn.b)
    assert dm.curves["host_ram"].b == de.model.curves["host_ram"].b
    # ServingDemand built from the estimate == built from the shim
    from repro.serve import ServingDemand
    a = ServingDemand.from_estimate(de, 48)
    b = ServingDemand.from_demand_model(dm, 48)
    assert (a.weights_gb, a.kv_gb_per_token, a.host_ram_per_req_gb) \
        == (b.weights_gb, b.kv_gb_per_token, b.host_ram_per_req_gb)


def test_conservative_serving_estimate_pads_kv_slope():
    from repro.configs import get_config
    cfg = get_config("qwen3-0.6b", smoke=True)
    exact = get_estimator("kv-growth").estimate(ModelTarget(cfg, 48))
    padded = get_estimator("conservative").estimate(ModelTarget(cfg, 48))
    assert padded.conservative and not exact.conservative
    assert padded.primary_fn.m == exact.primary_fn.m     # weights exact
    assert padded.primary_fn.b == pytest.approx(
        exact.primary_fn.b * 1.25)                       # KV padded
    from repro.serve import ServingDemand
    assert ServingDemand.from_estimate(padded, 48).kv_gb_per_token \
        > ServingDemand.from_estimate(exact, 48).kv_gb_per_token


def test_serving_net_axis_flows_into_demand():
    from repro.configs import get_config
    from repro.serve import ServingDemand
    cfg = get_config("qwen3-0.6b", smoke=True)
    de = get_estimator("kv-growth").estimate(
        ModelTarget(cfg, 48, net_gbps_per_req=0.25))
    assert de.model.curves["net"].b == 0.25
    sd = ServingDemand.from_estimate(de, 48)
    assert sd.extra_axes == {"net": 0.25}
    assert sd.per_request_axes() == {"net": 0.25}
    vec = sd.request_vector(_req(), 0)
    assert vec["net"] == 0.25


def _req():
    from repro.serve import Request
    return Request(rid=0, prompt_len=4, max_new_tokens=4)


# --- invariants per implementation -----------------------------------------

STAGED_AUX = {"host_ram": MemoryFunction("affine", 0.2, 0.4),
              "net": MemoryFunction("affine", 0.1, 1.5)}


def _staged_app(apps, i=0):
    from dataclasses import replace
    return replace(apps[i], aux_demand=dict(STAGED_AUX))


@pytest.mark.parametrize("name", JOB_ESTIMATORS)
def test_estimate_monotone_and_inverse_consistent(suite, name):
    """Every implementation's demand model is monotone in units, and
    inverting a budget yields units whose demand fits that budget."""
    apps, moe = suite
    app = _staged_app(apps, 5)
    est = _est(name, moe)
    de = est.estimate(JobTarget(app, 1000.0, primary_axis="hbm"),
                      rng=np.random.default_rng(3))
    model = de.model
    assert model.primary_axis == "hbm"
    assert {"host_ram", "net"} <= set(model.curves)
    grid = np.linspace(1.0, 120.0, 8)
    for a, fn in model.curves.items():
        ys = [float(fn(x)) for x in grid]
        assert all(y2 >= y1 - 1e-9 for y1, y2 in zip(ys, ys[1:])), a
    budget = ResourceVector(hbm=200.0, host_ram=12.0, net=30.0)
    units, axis = model.inverse(budget)
    assert np.isfinite(units) and units > 0
    assert axis in budget
    assert model.demand(units).fits(budget, eps=1e-6)


@pytest.mark.parametrize("name", JOB_ESTIMATORS)
def test_estimate_with_probes_skips_measurement(suite, name):
    """Passing measured probes calibrates from them — no target
    measurement, rng unused."""
    apps, moe = suite
    est = _est(name, moe)
    probes = [(5.0, 8.0), (10.0, 11.0), (20.0, 15.0)]
    de = est.estimate(JobTarget(apps[2], 200.0), probes)
    if name == "oracle":                 # prophetic: ignores probes
        assert de.primary_fn is apps[2].true_fn
        return
    fn = de.primary_fn
    for x, y in probes:
        assert float(fn(x)) == pytest.approx(y, rel=0.35)


def test_moe_predicts_declared_sidecar_curves(suite):
    """The moe estimator PREDICTS aux curves from probes: close to the
    declared ground truth, with net fitted by the linear contention
    model."""
    apps, moe = suite
    app = _staged_app(apps)
    de = _est("moe", moe).estimate(
        JobTarget(app, 1000.0, primary_axis="hbm"),
        rng=np.random.default_rng(0))
    assert de.model.curves["net"].family == "affine"
    for axis in ("host_ram", "net"):
        pred, true = de.model.curves[axis], STAGED_AUX[axis]
        for x in (10.0, 50.0, 100.0):
            assert float(pred(x)) == pytest.approx(float(true(x)),
                                                   rel=0.15)
        assert de.confidence[axis] > 0.5
        assert axis in de.info["aux_calib"]
    # the primary axis never collides with an aux curve
    assert de.model.primary_axis == "hbm"


def test_oracle_uses_ground_truth_everywhere(suite):
    apps, _ = suite
    app = _staged_app(apps, 3)
    de = get_estimator("oracle").estimate(
        JobTarget(app, 50.0, primary_axis="hbm"))
    assert de.primary_fn is app.true_fn
    assert de.model.curves["host_ram"] is app.aux_demand["host_ram"]
    assert all(c == 1.0 for c in de.confidence.values())
    assert not de.conservative


def test_conservative_always_flags(suite):
    apps, _ = suite
    de = get_estimator("conservative").estimate(
        JobTarget(apps[0], 100.0), rng=np.random.default_rng(1))
    assert de.conservative
    assert de.confidence["host_ram"] == 0.0
    assert de.info["confident"] is False


# --- deprecation + net end-to-end ------------------------------------------

def test_declared_aux_demand_legacy_path_warns(suite):
    """A job that reaches sizing WITHOUT an estimate (legacy policies)
    falls back to declared aux curves — with a DeprecationWarning."""
    apps, moe = suite
    from repro.core.simulator import Job, Policy
    pol = Policy(moe)
    app = _staged_app(apps)
    cfg = SimConfig(primary_axis="hbm",
                    extra_capacity={"host_ram": 8.0, "net": 20.0})
    job = Job(0, app, 100.0, 1.0, fn_hat=app.true_fn)   # no demand_est
    with pytest.warns(DeprecationWarning):
        dm = pol._demand_model(cfg, job)
    assert dm.curves["host_ram"] is app.aux_demand["host_ram"]
    # the estimator path is warning-free and uses PREDICTED curves
    pol.bind(cfg)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        pol.predict(job, np.random.default_rng(0))
        dm2 = pol._demand_model(cfg, job)
    assert dm2.curves["host_ram"] is not app.aux_demand["host_ram"]


def test_net_axis_binds_simulator_admission(suite):
    """net as a live axis end-to-end: predicted linear contention curve
    against a small per-host link budget binds admissions."""
    apps, moe = suite
    from dataclasses import replace
    netted = [replace(a, aux_demand={"net": MemoryFunction(
        "affine", 0.2, 1.0)}) for a in apps]
    cfg = SimConfig(n_hosts=4, host_mem_gb=4096.0, min_alloc_gb=4.0,
                    primary_axis="hbm", extra_capacity={"net": 30.0},
                    max_sim_time=1e7)
    sim = Simulator([(netted[i], 1000.0) for i in (0, 3, 7)],
                    OursPolicy(moe), cfg, seed=2)
    out = sim.run()
    assert out["binding_axes"].get("net", 0) > 0
    for h in sim.hosts:          # bookings never exceed the link budget
        used = sum(e.claimed_vec.get("net", 0.0) for e in h.execs)
        assert used <= 30.0 + 1e-6


# --- the controller built around an estimator ------------------------------

def test_admission_controller_admit_target(suite):
    """The one-call pipeline: estimate -> conservative-aware shading ->
    binding-axis inverse, through a controller-attached estimator."""
    from repro.sched import AdmissionController
    apps, moe = suite
    ctrl = AdmissionController(estimator=get_estimator("moe",
                                                       predictor=moe))
    free = ResourceVector(host_ram=32.0, cpu=1.0)
    dec = ctrl.admit_target(JobTarget(apps[0], 100.0), free,
                            rng=np.random.default_rng(0), cap=50.0)
    assert dec.units > 0
    est = dec.info["estimate"]
    assert isinstance(est, DemandEstimate)
    assert dec.booked.fits(dec.budget)
    # a name spec resolves through the registry; the conservative
    # estimate halves the shaded memory budget
    cons = AdmissionController(estimator="conservative")
    dec2 = cons.admit_target(JobTarget(apps[0], 100.0), free,
                             rng=np.random.default_rng(0))
    assert dec2.info["estimate"].conservative
    assert dec2.budget_gb == pytest.approx(16.0)     # 32 GB halved
    # no estimator attached -> loud failure, not a silent scalar path
    with pytest.raises(RuntimeError):
        AdmissionController().estimate(JobTarget(apps[0], 1.0))


def test_policy_rebind_keeps_owned_controller_in_sync(suite):
    """Re-binding a policy under a different SimConfig.estimator must
    update its owned controller's estimator handle too."""
    apps, moe = suite
    pol = OursPolicy(moe)
    pol.bind(SimConfig(n_hosts=2))
    assert pol.admission.estimator is pol._est
    first = pol._est
    pol.bind(SimConfig(n_hosts=2, estimator="conservative"))
    assert pol._est is not first
    assert pol.admission.estimator is pol._est
    # a caller-supplied shared controller is never clobbered
    from repro.sched import AdmissionController
    shared = AdmissionController(estimator="oracle")
    keep = shared.estimator
    pol2 = OursPolicy(moe, admission=shared)
    pol2.bind(SimConfig(n_hosts=2, estimator="conservative"))
    assert shared.estimator is keep


# --- online updates through the registry handle ----------------------------

def test_partial_update_flows_through_estimator_handle(suite):
    apps, moe = suite
    est = _est("moe", copy.deepcopy(moe))
    assert est.supports_online_update
    f = np.clip(apps[0].features + 0.4, 0, 1.2)
    assert est.partial_update(f, "affine") is True
    assert est.partial_update(f, "affine") is False     # dedupe
    fam, dist, conf = est.select_family(f)
    assert fam == "affine"
    # estimators without online learning drop the offer instead of
    # raising — the refresher counts it as a rejection
    cons = get_estimator("conservative")
    assert cons.partial_update(f, "affine") is False
    ref = OnlineRefresher(cons)
    out = ref.observe(f, [1.0, 2.0, 4.0], [1.0, 2.0, 4.0],
                      confident=False)
    assert out is None and ref.rejected == 1 and ref.accepted == 0


def test_refresher_accepts_through_moe_handle(suite):
    apps, moe = suite
    est = _est("moe", copy.deepcopy(moe))
    ref = OnlineRefresher(est)
    rng = np.random.default_rng(0)
    f = np.clip(apps[0].features + 0.5, 0, 1.5)
    xs = np.asarray([2.0, 5.0, 10.0, 20.0])
    ys = 0.5 + 0.8 * xs                       # cleanly affine
    out = ref.observe(f, xs, ys, confident=False)
    assert out == "affine" and ref.accepted == 1
    assert est.predictor.n_online_rows == 1
    assert rng is not None
